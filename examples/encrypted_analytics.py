"""End-to-end encrypted TPC-H analytics (the paper's evaluation, §5).

Runs the full nine-query benchmark on the mock backend at paper-scale
parameters (n=32768 slots, 30 limbs, t=65537) with both planner regimes,
verifies every result against the plaintext oracle, and prints the
refresh (bootstrap-equivalent) comparison that is the paper's headline.

    PYTHONPATH=src python examples/encrypted_analytics.py [--scale small]

`--workload` instead schedules the executable mix (Q1, Q6, Q12, Q19)
through the cross-query workload cache (engine/workload.py): a cold pass
batch-fuses every distinct circuit of all four queries, a warm pass
serves everything from the persistent noise-aware cache — the dashboard
scenario where repeated query mixes stop paying for their comparison
circuits.

    PYTHONPATH=src python examples/encrypted_analytics.py --workload
"""
import argparse
import time

from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.planner import Planner
from repro.engine.workload import WorkloadCache, run_workload


def run_workload_demo(bk, db, shards=None):
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache, shards=shards)
    plans = [Q.QUERIES[qn][0]() for qn in Q.PLAN_EXECUTABLE]
    print(f"{'pass':6s} {'ok':4s} {'launches':>9s} {'muls':>8s} "
          f"{'circuits':>9s} {'hits':>6s} {'wall_s':>7s}")
    walls, reps = {}, {}
    for label in ("cold", "warm"):
        t0 = time.time()
        rep = run_workload(pl, plans)
        walls[label], reps[label] = time.time() - t0, rep
        ok = rep.results == [Q.QUERIES[qn][2](db) for qn in Q.PLAN_EXECUTABLE]
        print(f"{label:6s} {str(ok):4s} {rep.launches:>9d} {rep.muls:>8d} "
              f"{rep.cache.misses:>9d} {rep.cache.hits:>6d} "
              f"{walls[label]:>7.2f}")
    print(f"\nwarm-cache speedup {walls['cold'] / walls['warm']:.2f}x wall, "
          f"warm hit rate {reps['warm'].hit_rate:.2f} — every comparison "
          f"circuit of the mix served from the persistent noise-aware cache.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small"])
    ap.add_argument("--workload", action="store_true",
                    help="cold/warm Q1+Q6+Q12+Q19 mix through the "
                         "cross-query workload cache")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard the block scans over N mesh data lanes "
                         "(engine/sharded.py); prints the modeled "
                         "distributed speedup per optimized query")
    args = ap.parse_args()
    scale = getattr(tpch.Scale, args.scale)()

    bk = MockBackend()
    db = tpch.load(bk, scale)
    print(f"loaded {sum(t.nrows for t in db.tables.values()):,} rows, "
          f"{sum(t.ct_count for t in db.tables.values())} ciphertexts "
          f"(paper profile: n=32768, logQ~881, t=65537)\n")
    if args.workload:
        run_workload_demo(bk, db, shards=args.shards)
        return

    # Measured per-op seconds extrapolated to paper parameters
    # (results/op_costs.json; see benchmarks/common.py) — used only to
    # price the --shards distribution ledger.
    costs = {"mul": 15.8, "mul_plain": 17.2, "mul_scalar": 0.72,
             "add": 0.46, "rotate": 33.1, "refresh": 44.0}
    shard_col = f" {'shard speedup':>14s}" if args.shards else ""
    print(f"{'query':5s} {'opt: ok':8s} {'muls':>7s} {'refresh':>8s}   "
          f"{'unopt: ok':9s} {'muls':>7s} {'refresh':>8s}{shard_col}")
    for qn in ["Q1", "Q4", "Q5", "Q6", "Q8", "Q12", "Q14", "Q17", "Q19"]:
        _, run_f, oracle_f = Q.QUERIES[qn]
        row = [qn]
        speedup = ""
        for optimized in (True, False):
            pl = Planner(db, optimized=optimized,
                         shards=args.shards if optimized else None)
            bk.stats.reset()
            t0 = time.time()
            ok = run_f(pl) == oracle_f(db)
            row += [str(ok), str(bk.stats.mul), str(bk.stats.refresh)]
            if optimized and pl.shard_ctx is not None:
                from repro.engine.sharded import ShardContext
                serial = ShardContext(1)
                serial.dist, serial.repl = pl.shard_ctx.dist, pl.shard_ctx.repl
                serial.folds = pl.shard_ctx.folds
                speedup = (f"{serial.modeled_seconds(costs) / pl.shard_ctx.modeled_seconds(costs):>13.2f}x")
        print(f"{row[0]:5s} {row[1]:8s} {row[2]:>7s} {row[3]:>8s}   "
              f"{row[4]:9s} {row[5]:>7s} {row[6]:>8s} {speedup}")
    print("\nrefresh = bootstrap-equivalent (44 s each at paper scale): "
          "the noise-aware planner's job is the left column staying ~0.")
    if args.shards:
        print(f"shard speedup = modeled scan time at 1 vs {args.shards} "
              f"mesh data lanes (distributed block lanes divide; "
              f"singleton work and psum combines do not).")


if __name__ == "__main__":
    main()
