"""Train a ~100M-parameter LM for a few hundred steps on the host
(deliverable b: end-to-end driver), with checkpoint/resume.

The config is a scaled-down starcoder2 (same code path as the 3B/72B
configs; the launcher shards it the same way on a pod).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TokenPipeline
from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime.checkpoint import CheckpointManager
from repro.train import steps as steps_mod


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-100m", d_model=512, n_layers=8, vocab=32768,
        n_heads=8, n_kv_heads=2, head_dim=64,
        pattern=("attn",), d_ff=2048, mlp_gated=False,
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"{cfg.name}: {lm.param_count(cfg)/1e6:.1f}M params")
    params = lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = steps_mod.init_opt(cfg, params)
    step = jax.jit(steps_mod.make_train_step(cfg, lr=3e-4),
                   donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    losses = []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")
        if (i + 1) % 100 == 0:
            ckpt.save(i + 1, params, opt, extra={"pipeline": pipe.state_dict()})
    ckpt.wait()
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
