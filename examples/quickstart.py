"""Quickstart: encrypted SQL in 60 lines.

Loads a tiny table under real RNS-BFV (t=257 micro parameters so it runs
in seconds), then evaluates

    SELECT SUM(price), COUNT(*) FROM sales
    WHERE day < 50 AND qty >= 3

entirely on ciphertexts — equality/range masks via arithmetic circuits,
aggregation via rotate-reduce — and decrypts only the final scalars.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.params import make_params
from repro.engine.backend import BFVBackend
from repro.engine.plan import Agg, And, Factor, Pred
from repro.engine.planner import Planner
from repro.engine.schema import ColumnSpec, TableSchema
from repro.engine.storage import Database


def main():
    print("keygen (n=128, t=257, 12 RNS limbs) ...")
    bk = BFVBackend(make_params(n=128, t=257, k=12), seed=0)

    rng = np.random.default_rng(42)
    n = 50
    data = {"day": rng.integers(1, 101, n),
            "price": rng.integers(1, 101, n),
            "qty": rng.integers(1, 11, n)}
    schema = TableSchema("sales", [ColumnSpec("day", "int"),
                                   ColumnSpec("price", "int"),
                                   ColumnSpec("qty", "int")])
    db = Database(bk)
    db.load_table(schema, data, n)
    print(f"encrypted {n} rows into {db.tables['sales'].ct_count} ciphertexts")

    pl = Planner(db, optimized=True)
    tbl = db.tables["sales"]
    where = And((Pred("day", "<", 50), Pred("qty", ">=", 3)))
    mask = pl.where_mask(tbl, where)

    total = pl.aggregate(tbl, Agg("sum", (Factor("price"),), "s"), mask)
    cnt = pl.aggregate(tbl, Agg("count", (), "c"), mask)

    sel = (data["day"] < 50) & (data["qty"] >= 3)
    got_sum, got_cnt = int(bk.decrypt(total)[0]), int(bk.decrypt(cnt)[0])
    print(f"SUM(price) = {got_sum}   (plaintext: {int(data['price'][sel].sum()) % bk.t})")
    print(f"COUNT(*)   = {got_cnt}   (plaintext: {int(sel.sum())})")
    print(f"ct-ct muls: {bk.stats.mul}, rotations: {bk.stats.rotate}, "
          f"refreshes: {bk.stats.refresh} (planner kept the budget)")
    assert got_sum == int(data["price"][sel].sum()) % bk.t
    assert got_cnt == int(sel.sum())
    print("OK")


if __name__ == "__main__":
    main()
