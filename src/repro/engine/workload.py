"""Persistent noise-aware mask cache + cross-query workload scheduling.

The CSE store of engine/physical.py used to be a bare dict on one
Planner: it died with the query mix and — the bug this module fixes —
served cached mask blocks with *no noise-level check*.  Mask blocks are
live ciphertext handles: a planned refresh inside one consumer mutates
them in place (engine/backend.py `_maybe_refresh`/`ensure_levels`), so a
cached entry's remaining noise budget drifts away from what a fresh
derivation would carry.  A later plan admitting that entry then executes
a noise trajectory its PlanReport never priced: refreshes the model
never predicted, or measured depth far below the Table-3 prediction —
either way `ExecReport.validate` trips.

`WorkloadCache` makes admission noise-aware (§4.3.2's i* rule applied at
the cache boundary): every entry records the levels its blocks carried
at birth, and a hit is served only after comparing the blocks' *current*
levels against the consumer's downstream multiplication count:

  serve               levels >= min(need, born_levels): the entry is at
                      least as good as re-deriving it, so the consumer's
                      noise model holds by construction.
  refresh-then-serve  degraded below the cold-equivalence bar: one
                      planned refresh at admission (charged to OpStats,
                      counted in `admit_refreshes`, reported separately
                      by ExecReport so it is never an *unpredicted*
                      refresh).
  re-derive           policy='rederive': drop the entry and re-run the
                      circuit inside the next fused launch instead.

The cache is keyed on `CmpAtom.key = (table, column, circuit, const,
flip, rhs)` and persists across planners and queries — the encrypted
analogue of PartitionCache's partition-key condition store: one cached
EQ/LT mask serves a whole dashboard's query mix.  `fk_lookup/fk_store`
additionally cache the per-parent-key join EQ banks of
`ops.translate_mask_down`, so repeated FK translations stop re-running
nparent EQ circuits.  Invalidation is wired to `Database.load_table`
through `bind()`: re-loading a table drops every entry derived from it.

`run_workload(planner, plans)` is the scheduler on top: it compiles a
*batch* of QueryPlans through one physical pass — every distinct
comparison circuit of every query in the batch is requested up front and
evaluated in ONE stacked launch per circuit shape (Q1+Q6+Q12+Q19's EQs
together, their LTs together) — then executes each plan against the warm
evaluator.  See DESIGN.md §8 for the keying/admission/invalidation
contract.
"""
from __future__ import annotations

import dataclasses

from ..runtime import faults


@dataclasses.dataclass
class CacheStats:
    """Hit/miss/refresh accounting for one WorkloadCache."""

    hits: int = 0                 # served entries born in an earlier run
    intra_hits: int = 0           # served entries born in the current run
    misses: int = 0               # atom circuits evaluated and inserted
    admit_refreshes: int = 0      # refresh-on-admit events (entries)
    admit_refresh_blocks: int = 0  # blocks refreshed at admission
                                   # (OpStats.refresh units, for netting)
    rederives: int = 0            # degraded entries dropped (policy)
    invalidations: int = 0        # entries dropped by table re-loads
    fk_hits: int = 0              # per-key join EQ bank reuses
    fk_misses: int = 0            # per-key join EQ banks built
    evictions: int = 0            # entries dropped by the LRU bound
    poison_drops: int = 0         # entries failing their content
                                  # fingerprint at serve (dropped or,
                                  # under integrity='fail', fatal)

    def clone(self) -> "CacheStats":
        return dataclasses.replace(self)

    @property
    def hit_rate(self) -> float:
        """Cross-query hit rate: served-from-a-previous-run over all
        cache-resolving lookups (intra-run reuse excluded — that is CSE,
        not workload caching)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def delta(self, start: "CacheStats") -> "CacheStats":
        out = CacheStats()
        for f in dataclasses.fields(CacheStats):
            setattr(out, f.name, getattr(self, f.name) - getattr(start, f.name))
        return out


@dataclasses.dataclass
class CacheEntry:
    blocks: list                  # live ciphertext handles (mutable noise)
    table: str
    born_levels: int              # min levels_left across blocks at insert
    born_run: int                 # begin_run() epoch that derived it
    fp: list | None = None        # content fingerprints at insert (None
                                  # when the backend's handles are opaque
                                  # — real BFV — or integrity is off)


class WorkloadCache:
    """Persistent encrypted-mask store with noise-aware admission.

    One instance outlives planners and queries; pass it to
    `Planner(db, cache=...)` to share masks across a workload.  All
    mutation of entry noise happens through the live block handles —
    admission reads `bk.levels_left` at serve time, never a snapshot.
    """

    def __init__(self, policy: str = "refresh", max_entries: int | None = None,
                 integrity: str = "rederive"):
        assert policy in ("refresh", "rederive"), policy
        assert max_entries is None or max_entries > 0, max_entries
        assert integrity in ("off", "rederive", "fail"), integrity
        self.policy = policy
        # At-rest integrity: entries record content fingerprints at
        # insert and re-verify at serve.  'rederive' (default) silently
        # drops a tampered entry and lets the consumer re-run the
        # circuit; 'fail' raises a typed CachePoisonFault; 'off' skips
        # the check.  Opaque backends (real BFV) degrade to 'off'
        # automatically — see _BackendBase.fingerprint.
        self.integrity = integrity
        # LRU bound, applied independently to the atom store and the FK
        # bank store.  None = unbounded (the historical behaviour).  A
        # hit moves its entry to the MRU end; insertion past the bound
        # pops the LRU end and counts it in `stats.evictions`.
        self.max_entries = max_entries
        self.entries: dict[tuple, CacheEntry] = {}
        self.fk_banks: dict[tuple, CacheEntry] = {}
        self.stats = CacheStats()
        self._run = 0
        self._budget: dict[int, int] = {}      # id(bk) -> budget levels

    # ------------------------------------------------------------- wiring
    def bind(self, db) -> None:
        """Subscribe to `Database.load_table` so re-loading a table drops
        every mask derived from its (now replaced) ciphertexts."""
        db.add_reload_hook(self._on_table_load)

    def _on_table_load(self, table: str) -> None:
        self.invalidate_table(table)

    def invalidate_table(self, table: str) -> None:
        dead = [k for k, e in self.entries.items() if e.table == table]
        for k in dead:
            del self.entries[k]
        dead_banks = [k for k, e in self.fk_banks.items() if e.table == table]
        for k in dead_banks:
            del self.fk_banks[k]
        self.stats.invalidations += len(dead) + len(dead_banks)

    def clear(self) -> None:
        self.stats.invalidations += len(self.entries) + len(self.fk_banks)
        self.entries.clear()
        self.fk_banks.clear()

    def __len__(self) -> int:
        return len(self.entries)

    # --------------------------------------------------------------- runs
    def begin_run(self) -> int:
        """Open a new derivation epoch: entries inserted from now on are
        'this run's' — serving them again within the run is CSE
        (intra_hits), serving them from a later run is a workload hit."""
        self._run += 1
        return self._run

    # ------------------------------------------------------------ budget
    def _budget_levels(self, bk) -> int:
        key = id(bk)
        if key not in self._budget:
            from .planner import noise_budget_levels
            self._budget[key] = noise_budget_levels(bk)
        return self._budget[key]

    # -------------------------------------------------------------- atoms
    def contains(self, key: tuple) -> bool:
        return key in self.entries

    def usable(self, bk, atom, need_levels: int) -> bool:
        """Whether a request for `atom` can be satisfied without running
        its circuit (under the current admission policy)."""
        e = self.entries.get(atom.key)
        if e is None:
            return False
        if self.policy != "rederive":
            return True                        # refresh-on-admit always serves
        have = min(bk.levels_left(b) for b in e.blocks)
        return have >= min(need_levels, e.born_levels)

    def _touch(self, store: dict, key) -> None:
        """Move `key` to the MRU end of the insertion-ordered store."""
        store[key] = store.pop(key)

    def _evict(self, store: dict) -> None:
        if self.max_entries is None:
            return
        while len(store) > self.max_entries:
            store.pop(next(iter(store)))       # LRU = oldest-ordered key
            self.stats.evictions += 1

    # ---------------------------------------------------------- integrity
    def _fps(self, bk, flat_blocks):
        if self.integrity == "off":
            return None
        return faults.fingerprint_blocks(bk, flat_blocks)

    def _intact(self, bk, key, entry, flat_blocks, store: dict) -> bool:
        """Re-verify an entry's content fingerprints at serve time.  A
        mismatch means the ciphertext payload changed outside the
        legitimate mutation channel (refresh touches only noise) — the
        cache-poison fault class.  The entry is dropped either way;
        integrity='fail' escalates to a typed fault."""
        if entry.fp is None:
            return True
        now = faults.fingerprint_blocks(bk, flat_blocks)
        if now == entry.fp:
            return True
        del store[key]
        self.stats.poison_drops += 1
        if self.integrity == "fail":
            raise faults.CachePoisonFault(
                f"cache entry {key} failed its content fingerprint "
                f"({len([a for a, b in zip(entry.fp, now) if a != b])} of "
                f"{len(entry.fp)} blocks tampered)",
                stage="cache-serve", detail={"key": list(map(str, key))})
        return False

    def insert(self, bk, atom, blocks: list) -> None:
        self.entries[atom.key] = CacheEntry(
            blocks, atom.table,
            min(bk.levels_left(b) for b in blocks), self._run,
            self._fps(bk, blocks))
        self.stats.misses += 1
        self._evict(self.entries)

    def serve(self, bk, atom, need_levels: int):
        """Noise-aware admission (the fix for the noise-unaware CSE hit).

        `need_levels` is the consumer's downstream multiplication count —
        the same quantity the i* rule sizes planned refreshes with.  The
        cold-equivalence bar is min(need, born_levels): a fresh
        derivation could not do better than born_levels either, so a plan
        whose model already prices a mid-chain refresh keeps paying it
        identically.  Returns the block list, or None on miss/re-derive.
        """
        e = self.entries.get(atom.key)
        if e is None:
            return None
        if not self._intact(bk, atom.key, e, e.blocks, self.entries):
            return None                      # poisoned: force re-derive
        have = min(bk.levels_left(b) for b in e.blocks)
        required = min(need_levels, e.born_levels)
        if have < required:
            if self.policy == "rederive":
                del self.entries[atom.key]
                self.stats.rederives += 1
                return None
            want = min(need_levels, self._budget_levels(bk))
            for b in e.blocks:
                if bk.levels_left(b) < want:
                    bk.ensure_levels(b, want)
                    self.stats.admit_refresh_blocks += 1
            self.stats.admit_refreshes += 1
        if e.born_run < self._run:
            self.stats.hits += 1
        else:
            self.stats.intra_hits += 1
        self._touch(self.entries, atom.key)
        return e.blocks

    # ----------------------------------------------- per-key join EQ banks
    def fk_lookup(self, bk, table: str, fk: str, nparent: int):
        """Cached `_per_key_eq` bank for (child table, fk, nparent).
        Each per-key mask absorbs exactly one ct-ct multiply before the
        translate accumulation, so admission needs one level."""
        e = self.fk_banks.get((table, fk, nparent))
        if e is None:
            return None
        flat = [b for masks in e.blocks for b in masks]
        if not self._intact(bk, (table, fk, nparent), e, flat, self.fk_banks):
            return None                      # poisoned: rebuild the bank
        if any(bk.levels_left(b) < 1 for masks in e.blocks for b in masks):
            del self.fk_banks[(table, fk, nparent)]   # degraded: rebuild
            self.stats.rederives += 1
            return None
        self.stats.fk_hits += 1
        self._touch(self.fk_banks, (table, fk, nparent))
        return e.blocks

    def fk_store(self, bk, table: str, fk: str, nparent: int, bank: list) -> None:
        flat = [b for masks in bank for b in masks]
        self.fk_banks[(table, fk, nparent)] = CacheEntry(
            bank, table, min(bk.levels_left(b) for b in flat), self._run,
            self._fps(bk, flat))
        self.stats.fk_misses += 1
        self._evict(self.fk_banks)


# ---------------------------------------------------------------------------
# Cross-query fused scheduling.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkloadReport:
    """One `run_workload` pass: per-query results/reports + the cache and
    op-stat deltas attributable to the batch."""

    results: list
    reports: list
    cache: CacheStats             # delta over this pass
    launches: int
    muls: int
    refreshes: int

    @property
    def hit_rate(self) -> float:
        return self.cache.hit_rate


def run_workload(planner, plans, validate: bool = True,
                 verify: bool | None = None) -> WorkloadReport:
    """Compile a batch of QueryPlans through ONE physical pass.

    Optimized regime: all plans' mask trees are lowered and their atoms
    requested against a single shared AtomEvaluator before anything runs,
    so same-shape comparison circuits fuse *between* queries into one
    stacked launch (the cross-query generalization of per-query fusion).
    Atoms already in the planner's WorkloadCache are admitted noise-aware
    and never re-run.  Each plan then executes against the warm evaluator
    and validates its ExecReport as usual.

    Unoptimized planners (or fuse_masks=False) fall back to sequential
    per-plan execution — the classical no-sharing baseline.

    `verify` overrides the planner's static-verification knob for this
    batch only (None keeps the planner default); each plan is verified
    against the warm cache state right before it executes.
    """
    from .executor import Executor
    bk = planner.bk
    cache = planner.mask_cache
    cs0 = cache.stats.clone()
    s0 = bk.stats.clone()
    results, reports = [], []
    prev_verify = getattr(planner, "verify_plans", True)
    if verify is not None:
        planner.verify_plans = verify
    try:
        if planner.optimized and planner.fuse_masks:
            ev = planner.evaluator()
            cache.begin_run()                 # batch derivation epoch
            compiled = []
            for plan in plans:
                ex = Executor(planner, evaluator=ev)
                cq = ex.compile(plan)
                ex.request_atoms(cq, ev)
                compiled.append((ex, cq))
            ev.flush()                        # one stacked launch per shape
            for ex, cq in compiled:
                results.append(ex.run_compiled(cq, validate=validate))
                reports.append(ex.report)
        else:
            for plan in plans:
                ex = Executor(planner)
                results.append(ex.run(plan, validate=validate))
                reports.append(ex.report)
    finally:
        planner.verify_plans = prev_verify
    s1 = bk.stats
    return WorkloadReport(
        results=results, reports=reports,
        cache=cache.stats.delta(cs0),
        launches=s1.launches - s0.launches,
        muls=s1.mul - s0.mul,
        refreshes=s1.refresh - s0.refresh)
