"""Noise-sensitive query optimization (paper §4.3).

The planner's job is to keep every multiplication chain inside the noise
budget B (levels) so the engine never refreshes.  It implements the three
rewrites of §4.3.2 and exposes the same building blocks in two regimes:

  optimized   R1 mask isolation: every predicate is evaluated against the
              *original* columns into its own mask subgraph.
              R2 independent evaluation: conjunctions become balanced
              product trees (depth max+log k instead of max+k-1).
              R3 late injection: the combined mask is multiplied into the
              plan exactly once, at the deepest point that still fits the
              budget (the i* rule below).

  unoptimized the classical pipeline: predicate pushdown multiplies masks
              into columns immediately, so later comparisons run on
              deepened inputs and chains add up — exactly the Fig. 3(a)
              behaviour whose depth is m stages x d_s each.

Cost-and-decision model (§4.3.2): for a fragment of m stages of per-stage
depth d_s, injecting the mask after stage i leaves depth D_i = (m-i)*d_s
on top of the mask and costs i extra mask multiplications:

    Cost(i) = (m-i)*C_mul + i*C_mul + [D_i > B] * C_boot
    i*      = max{ i : D_i <= B }   if feasible else m (pay one refresh)

In the optimized regime, mask construction, group-by enumeration and
ORDER BY all route through the physical IR (engine/physical.py): masks
compile to CmpAtom DAG nodes that are CSE-deduplicated on the planner's
`mask_cache` and fused into cross-column batched circuit launches; see
engine/executor.py for whole-plan execution (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import math

from ..core import compare as cmp
from . import ops
from .plan import And, Not, Or, Pred, QueryPlan, Translated, child_depth, eq_depth
from .storage import Database, EncryptedTable


def noise_budget_levels(bk) -> int:
    """How many sequential ct-ct multiplications a fresh ciphertext
    supports under this backend's parameters — B_noise in levels."""
    m = bk.model
    v = m.fresh()
    d = 0
    while True:
        v2 = m.keyswitch(m.mul(v, v))
        if m.budget(v2) <= 0:
            return d
        v, d = v2, d + 1


def injection_depth(m_stages: int, d_s: int, budget: int) -> int:
    """i* from the §4.3.2 cost model."""
    for i in range(m_stages + 1):
        if (m_stages - i) * d_s <= budget:
            return i
    return m_stages


@dataclasses.dataclass
class PlanReport:
    name: str
    optimized: bool
    predicted_depth: int
    budget_levels: int
    predicted_refreshes: int

    @property
    def fits(self) -> bool:
        return self.predicted_depth <= self.budget_levels


class Planner:
    def __init__(self, db: Database, optimized: bool = True, cache=None,
                 shards: int | None = None, mesh="auto",
                 guards: bool = False, limb_shards: int | None = None,
                 verify: bool = True):
        from .workload import WorkloadCache
        self.db = db
        self.bk = db.bk
        self.optimized = optimized
        self.budget_levels = noise_budget_levels(self.bk)
        # Static admission (DESIGN §10): the executor verifies every
        # compiled plan against the abstract noise/level/placement model
        # before touching ciphertexts; verify=False opts out (chaos
        # harnesses and benchmarks that deliberately run broken plans).
        self.verify_plans = verify
        # Sharded execution (DESIGN §4): shards=N partitions every
        # stacked block column over the mesh "data" axis; limb_shards=M
        # partitions each block's k RNS limbs over the "model" axis
        # (key-switches all-gather their digits across it).  The
        # executor and evaluator activate this context around
        # execution; None/None keeps the classic single-device path.
        if (shards is not None and shards >= 1) or (
                limb_shards is not None and limb_shards >= 1):
            from .sharded import make_shard_context
            self.shard_ctx = make_shard_context(
                shards if shards is not None else 1, mesh,
                limb_shards=limb_shards if limb_shards is not None else 1,
                limbs=getattr(self.bk, "limbs", None),
                ring_n=getattr(self.bk, "slots", 0))
        else:
            self.shard_ctx = None
        # Noise-aware mask store shared by every compiled mask: WHERE
        # predicates, group-by EQ enumerations, aux/join masks and sort
        # passes all read and write the same subgraph store through
        # noise-checked admission.  Pass an external WorkloadCache to
        # persist masks across planners/queries (engine/workload.py).
        self.mask_cache = cache if cache is not None else WorkloadCache()
        self.mask_cache.bind(db)       # invalidate on table re-loads
        # Scheduler knobs (benchmarks flip these to measure the pre-DAG
        # schedule): fuse_masks batches distinct circuits cross-column,
        # share_masks enables the CSE cache.  Both default to the regime.
        self.fuse_masks = optimized
        self.share_masks = optimized
        # Fault-tolerant runtime (DESIGN §9): guards=True arms the
        # decrypt-boundary headroom check, the plaintext sentinel lane
        # and bounded overflow recovery even outside an injection scope
        # (the executor always guards while a FaultPlan is armed).
        self.guards = guards
        # Elastic wiring: attach_straggler_detector populates these;
        # after every sharded run the executor synthesizes per-shard
        # heartbeats from the cost-ledger delta, reports them, and
        # re-shards away excluded workers.
        self.straggler_det = None
        self.op_costs: dict | None = None

    def attach_straggler_detector(self, det, costs: dict) -> None:
        """Wire a runtime/elastic.py StragglerDetector into execution:
        per-shard step times come from `ShardContext.heartbeats` priced
        with `costs` (measured per-op seconds), and exclusion feeds
        `ShardContext.reshard` — the scan-axis elasticity loop."""
        self.straggler_det = det
        self.op_costs = dict(costs)

    def evaluator(self):
        """A physical-atom evaluator bound to this planner's mask cache;
        circuit fusion is enabled only in the optimized regime.  With
        sharing disabled the evaluator gets a private throwaway store."""
        from .physical import AtomEvaluator
        return AtomEvaluator(self.db, self.bk,
                             self.mask_cache if self.share_masks else None,
                             fuse=self.fuse_masks, shard_ctx=self.shard_ctx)

    def translate_levels(self, downstream_muls: int) -> int:
        """Planned-refresh sizing for a mask about to cross an FK hop —
        the i* rule on levels: the translated bit must absorb the hop
        internals (broadcast + EQ x bit, ~2 levels) plus every downstream
        mask product; if that exceeds the whole budget the infeasible
        branch pays its single planned refresh inside ensure_levels."""
        return min(2 + downstream_muls, self.budget_levels)

    def verify(self, plan: QueryPlan):
        """Statically verify `plan` against this planner's state (noise
        abstract interpretation + IR typing + mesh lint, engine/verify.py)
        without executing it.  Returns a VerifyReport."""
        from .verify import verify_plan
        return verify_plan(self, plan)

    # ------------------------------------------------------------- report
    def report(self, plan: QueryPlan) -> PlanReport:
        t = self.bk.t
        d = plan.total_depth(t, self.optimized)
        boots = 0 if d <= self.budget_levels else math.ceil(
            (d - self.budget_levels) / max(self.budget_levels, 1))
        return PlanReport(plan.name, self.optimized, d, self.budget_levels, boots)

    # ------------------------------------------------- mask construction
    def where_mask(self, table: EncryptedTable, expr) -> list:
        """Evaluate a MaskExpr tree into one mask per block.

        Optimized regime: the tree is lowered through engine/physical.py
        — R1 isolation becomes a set of CmpAtoms (CSE-deduplicated on the
        planner cache), all atoms sharing a circuit shape run in one
        fused cross-column launch, and the combine layers replay R2's
        balanced trees.  Unoptimized keeps the sequential pipeline."""
        if not self.optimized:
            return self._mask_seq(table, expr)
        from .physical import annotate_downstream, compile_mask, run_mask_node
        from .sharded import activate
        node = compile_mask(self.db, table, expr)
        annotate_downstream(node, 1)     # R3: one injection at the aggregate
        ev = self.evaluator()
        with activate(self.bk, self.shard_ctx):
            ev.request_tree(node)
            ev.flush()
            return run_mask_node(node, ev, self)

    def _mask_seq(self, table, expr) -> list:
        """Unoptimized: classical pipeline semantics.  Conjunctions chain
        sequentially (depth max + k - 1 instead of max + log k); the far
        deeper pushdown penalty — joins running over already-masked
        columns, Fig. 3(a)'s 3*log(p-1) chains — lives in the unoptimized
        branches of the query bodies (translate-after-filter)."""
        bk = self.bk
        if isinstance(expr, Pred):
            return ops.pred_mask(bk, table, expr)
        if isinstance(expr, Not):
            return ops.not_mask(bk, self._mask_seq(table, expr.child))
        if isinstance(expr, Translated):
            parent = self.db.tables[expr.hop.parent]
            pm = self._mask_seq(parent, expr.expr)
            assert len(pm) == 1, "translated: single-block parent"
            return ops.translate_mask_down(bk, pm[0],
                                           self.db.tables[expr.hop.child],
                                           expr.hop.fk, parent.nrows)
        kids = [self._mask_seq(table, c) for c in expr.children]
        if isinstance(expr, Or):
            return ops.or_masks_seq(bk, kids)
        return ops.and_masks_seq(bk, kids)

    # ------------------------------------------------------- aggregation
    def aggregate(self, table: EncryptedTable, agg, mask: list | None):
        """SUM/COUNT/AVG with R3 late injection in the optimized regime:
        the mask meets the fully-formed expression exactly once, at the
        aggregation input."""
        bk = self.bk
        if mask is not None:
            mask = ops.apply_validity(bk, mask, table)
        if agg.kind == "count":
            assert mask is not None
            return ops.count(bk, mask)
        if self.optimized or mask is None:
            vals = ops.expr_blocks(bk, table, agg.factors)
            if mask is None:
                v = table.validity(table.nblocks - 1)
                if v is not None:
                    vals = vals[:-1] + [bk.mul_plain(vals[-1], v)]
                return ops.reduce_blocks(bk, vals)
            if agg.kind == "avg":
                return (ops.masked_sum(bk, vals, mask), ops.count(bk, mask))
            return ops.masked_sum(bk, vals, mask)
        # Unoptimized: mask every column first, then form the expression
        # on filtered inputs (pushdown).
        mask = ops.admit_inject(bk, mask)
        masked = {
            f.col: ops.mask_columns(bk, table.col(f.col).blocks, mask)
            for f in agg.factors if f.col is not None
        }
        vals = ops.expr_blocks(bk, table, agg.factors, masked=masked)
        if agg.kind == "avg":
            return (ops.reduce_blocks(bk, vals), ops.count(bk, mask))
        return ops.reduce_blocks(bk, vals)

    # ----------------------------------------------- group-by / order-by
    def group_masks(self, table: EncryptedTable, col: str, domain) -> list:
        """Per-value EQ masks for GROUP BY / ORDER BY enumeration.

        Optimized: memoized on the planner's CSE cache and fused into a
        single stacked launch for all uncached values — repeated group
        pairs (Q1), sorts after grouping, and re-run queries all reuse
        the identical `eq_scalar` subgraphs.  Unoptimized recomputes,
        like the classical pipeline it models."""
        if not self.optimized:
            return ops.group_masks(self.bk, table, col, domain)
        return self.evaluator().eq_masks(table, col, domain)

    def sort_column(self, table: EncryptedTable, col: str, domain,
                    descending: bool = False):
        """§4.2.3 ORDER BY through the memoized EQ-mask store."""
        if not self.optimized:
            return ops.sort_column(self.bk, table, col, domain, descending)
        masks = dict(self.group_masks(table, col, domain))
        return ops.sort_column(self.bk, table, col, domain, descending,
                               mask_provider=lambda v: masks[v])

    # ------------------------------------------------------------- joins
    def semi_join_mask(self, hop, parent_mask_block) -> list:
        """Translate a parent-row mask to the child through hop.fk."""
        child = self.db.tables[hop.child]
        nparent = self.db.tables[hop.parent].nrows
        return ops.translate_mask_down(self.bk, parent_mask_block, child, hop.fk, nparent)

    def group_aggregate(self, table: EncryptedTable, group_col: str, domain,
                        aggs, mask: list | None):
        """GROUP BY: one EQ mask per group value, combined with the WHERE
        mask (optimized: one balanced multiply; unoptimized: the group EQ
        is evaluated on masked columns)."""
        bk = self.bk
        results = {}
        if mask is not None:
            mask = ops.apply_validity(bk, mask, table)
        for v, gmask in self.group_masks(table, group_col, domain):
            if mask is None:
                m = gmask
            elif self.optimized:
                m = ops.mul_lists(bk, gmask, mask)
            else:
                col = table.col(group_col)
                filtered = ops.mask_columns(bk, col.blocks, mask)
                gm = [cmp.eq_scalar(bk, ct, int(v)) for ct in filtered]
                m = ops.mul_lists(bk, gm, mask)
            row = {}
            for agg in aggs:
                row[agg.name] = self._agg_with_mask(table, agg, m)
            results[v] = row
        return results

    def _agg_with_mask(self, table, agg, m):
        bk = self.bk
        if agg.kind == "count":
            return ops.count(bk, m)
        vals = ops.expr_blocks(bk, table, agg.factors)
        if agg.kind == "avg":
            return (ops.masked_sum(bk, vals, m), ops.count(bk, m))
        return ops.masked_sum(bk, vals, m)
