"""repro.engine — NSHEDB's scan-first encrypted query engine.

Layers:
  backend   duck-typed HE ops: BFVBackend (real ciphertexts) and
            MockBackend (Z_t arrays + identical noise/op accounting)
  schema    column types, dictionary encoding, fixed-point decimals
  storage   encrypted columnar tables (packed ciphertext blocks)
  ops       physical scan-first operators (masks, aggregates, join, ...)
  plan      logical plan nodes + the Table-3 depth model
  planner   noise-aware rewrites R1/R2/R3 + the i* injection cost model
  tpch      TPC-H datagen + plaintext oracle
  queries   the paper's nine benchmark queries (Q1,4,5,6,8,12,14,17,19)
  baseline  HE3DB / ArcEDB cost models for the comparison tables
"""
from .backend import BFVBackend, MockBackend, OpStats  # noqa: F401
