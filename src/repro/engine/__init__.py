"""repro.engine — NSHEDB's scan-first encrypted query engine.

Layers:
  backend   duck-typed HE ops: BFVBackend (real ciphertexts) and
            MockBackend (Z_t arrays + identical noise/op accounting)
  schema    column types, dictionary encoding, fixed-point decimals
  storage   encrypted columnar tables (packed ciphertext blocks)
  ops       physical scan-first operators (masks, aggregates, join, ...)
  plan      logical plan nodes (incl. Translated/AuxMask join forms) +
            the Table-3 depth model
  planner   noise-aware rewrites R1/R2/R3 + the i* injection cost model,
            CSE mask cache, memoized group/sort EQ masks
  physical  logical->physical lowering: CmpAtoms, CSE keys, cross-mask
            circuit fusion (DESIGN.md §7)
  executor  run_via_plan: scheduled operator-DAG execution + ExecReport
            asserted against the planner's predictions
  tpch      TPC-H datagen + plaintext oracle
  queries   the paper's nine benchmark queries (Q1,4,5,6,8,12,14,17,19);
            Q1/Q6/Q12/Q19 also execute through the compiled DAG
  baseline  HE3DB / ArcEDB cost models for the comparison tables
"""
from .backend import BFVBackend, MockBackend, OpStats  # noqa: F401
from .executor import ExecReport, run_via_plan  # noqa: F401
