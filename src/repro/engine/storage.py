"""Encrypted columnar storage (paper §4.1).

A column is a list of packed ciphertext blocks, S = slots values each;
the last block is zero-padded (PAD = 0 is outside every encoded domain).
Row counts, block counts and dictionary sizes are public metadata — the
leakage profile L the paper defines in §3.

The scan-first architecture means operators stream over blocks; there are
deliberately no indexes (Table 1: packing forces O(n) behaviour anyway).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .schema import ColumnSpec, TableSchema, validate_domain


@dataclasses.dataclass
class EncryptedColumn:
    name: str
    spec: ColumnSpec
    blocks: list[Any]                 # backend ciphertext handles
    nrows: int

    @property
    def nblocks(self) -> int:
        return len(self.blocks)


@dataclasses.dataclass
class EncryptedTable:
    name: str
    schema: TableSchema
    columns: dict[str, EncryptedColumn]
    nrows: int
    slots: int

    @property
    def nblocks(self) -> int:
        return (self.nrows + self.slots - 1) // self.slots

    def validity(self, block: int) -> np.ndarray | None:
        """Plaintext 0/1 vector of live rows in `block`; None if full."""
        full = self.slots
        if block < self.nblocks - 1 or self.nrows % full == 0:
            return None
        v = np.zeros(full, dtype=np.int64)
        v[: self.nrows - block * full] = 1
        return v

    def col(self, name: str) -> EncryptedColumn:
        return self.columns[name]

    @property
    def ct_count(self) -> int:
        return sum(c.nblocks for c in self.columns.values())


class Database:
    """A set of encrypted tables bound to one backend + plaintext shadow
    copies (the client's view, used only by tests/oracle — never by the
    engine operators)."""

    def __init__(self, backend):
        self.bk = backend
        self.tables: dict[str, EncryptedTable] = {}
        self.plain: dict[str, dict[str, np.ndarray]] = {}
        # Invalidation subscribers: called with the table name whenever a
        # table is (re)loaded — derived artifacts (cached masks) must not
        # outlive the ciphertexts they were computed from.
        self._reload_hooks: list = []

    def add_reload_hook(self, fn) -> None:
        if fn not in self._reload_hooks:
            self._reload_hooks.append(fn)

    def load_table(self, schema: TableSchema, data: dict[str, Any], nrows: int) -> EncryptedTable:
        bk = self.bk
        S = bk.slots
        cols: dict[str, EncryptedColumn] = {}
        shadow: dict[str, np.ndarray] = {}
        for spec in schema.columns:
            enc = spec.encode(data[spec.name])
            assert len(enc) == nrows, f"{schema.name}.{spec.name}: {len(enc)} != {nrows}"
            validate_domain(enc, bk.t, f"{schema.name}.{spec.name}")
            shadow[spec.name] = enc
            blocks = []
            for b0 in range(0, nrows, S):
                chunk = enc[b0 : b0 + S]
                blocks.append(bk.encrypt(chunk))
            cols[spec.name] = EncryptedColumn(spec.name, spec, blocks, nrows)
        tbl = EncryptedTable(schema.name, schema, cols, nrows, S)
        self.tables[schema.name] = tbl
        self.plain[schema.name] = shadow
        for fn in self._reload_hooks:
            fn(schema.name)
        return tbl

    def storage_bytes(self) -> int:
        per_ct = getattr(self.bk, "params", None)
        if per_ct is not None:
            ct_bytes = per_ct.ct_bytes
        else:
            ct_bytes = self.bk.profile.ct_bytes
        return ct_bytes * sum(t.ct_count for t in self.tables.values())

    def raw_bytes(self, bits: int = 16) -> int:
        return sum(t.nrows * len(t.schema.columns) * bits // 8 for t in self.tables.values())
