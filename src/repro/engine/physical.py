"""Logical -> physical lowering of mask expressions (DESIGN.md §7).

This module turns the declarative `MaskExpr` trees of engine/plan.py into
a small physical IR that the noise-aware scheduler can optimize before a
single ciphertext is touched:

  CmpAtom      one comparison *circuit* application: an affine shift
               z = ±col + c (or col - rhs_col) followed by either the
               EQ square chain (`eq_zero`) or the sgn/Paterson-Stockmeyer
               interpolant (`lt_zero`).  Every predicate in the SQL
               surface lowers to 1..k atoms plus cheap post-processing —
               the expensive part of query evaluation is exactly the set
               of distinct atoms.
  PredProgram  the atoms of one predicate plus its combiner (negate /
               product for BETWEEN / balanced sum for IN).
  MaskNode     the lowered expression tree: pred | and | or | not |
               translated (FK push-down of a parent-table subtree).

Two scheduler optimizations act on the atom set:

  CSE          atoms are keyed on (table, column, circuit, shift); the
               planner-wide WorkloadCache (engine/workload.py) means
               `l_returnflag = 'A'` is evaluated once no matter how many
               group pairs, sort passes or repeated queries mention it —
               and every hit passes noise-aware admission, so cached
               masks are refreshed or re-derived (never served blind)
               when a deeper consumer needs more remaining levels.
  Fusion       all *distinct* atoms that share a circuit shape — every
               EQ in the query, every LT in the query — are stacked
               across columns (and tables) into one `(nblocks_total, ...)`
               batch and run through a single circuit call: the
               cross-column generalization of the per-column batched path
               (one `(ncols*nblocks, 2, k, n)` Pallas launch on the BFV
               backend instead of one launch per predicate).

Both preserve the noise/depth accounting exactly: ops are charged per
block, every atom's z starts from fresh column blocks (equal noise), so
OpStats totals, refresh behaviour and `max_depth` match the unfused
schedule minus the work CSE provably removed.
"""
from __future__ import annotations

import dataclasses
import math

from ..core import compare as cmp
from .plan import And, JoinHop, Not, Or, Pred, Translated
from .storage import EncryptedTable


# ---------------------------------------------------------------------------
# Atoms.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CmpAtom:
    """One comparison-circuit application over a whole column.

    z = col - const            (flip=False)   |  col - rhs  (rhs set)
    z = const - col            (flip=True)    |  rhs - col
    followed by circuit 'eq' (eq_zero) or 'lt' (lt_zero).
    """

    table: str
    col: str
    circuit: str                  # 'eq' | 'lt'
    const: int = 0                # encoded comparison constant
    flip: bool = False
    rhs: str | None = None

    @property
    def key(self):
        return (self.table, self.col, self.circuit, self.const, self.flip, self.rhs)


@dataclasses.dataclass
class PredProgram:
    """Atoms of one predicate + the cheap combiner that rebuilds it."""

    atoms: list
    negs: list                    # post-circuit negation per atom (1 - m)
    combine: str                  # 'one' | 'mul' | 'sum' | 'zero'
    table: str = ""               # source table/column (for the 'zero' case)
    col: str = ""


def compile_pred(table: EncryptedTable, pred: Pred) -> PredProgram:
    """Lower one Pred to atoms, reproducing core/compare.py circuits
    op-for-op (see eq_scalar / lt_scalar / between_scalar / in_set)."""
    tname = table.name
    if pred.rhs_col is not None:
        a = lambda circ, flip: CmpAtom(tname, pred.col, circ, 0, flip, pred.rhs_col)
        return {
            "=":  PredProgram([a("eq", False)], [False], "one"),
            "!=": PredProgram([a("eq", False)], [True], "one"),
            "<":  PredProgram([a("lt", False)], [False], "one"),
            ">":  PredProgram([a("lt", True)], [False], "one"),
            ">=": PredProgram([a("lt", False)], [True], "one"),
            "<=": PredProgram([a("lt", True)], [True], "one"),
        }[pred.op]
    spec = table.col(pred.col).spec
    enc = spec.encode_scalar
    a = lambda circ, c, flip=False: CmpAtom(tname, pred.col, circ, int(c), flip)
    if pred.op == "=":
        return PredProgram([a("eq", enc(pred.value))], [False], "one")
    if pred.op == "!=":
        return PredProgram([a("eq", enc(pred.value))], [True], "one")
    if pred.op == "<":
        return PredProgram([a("lt", enc(pred.value))], [False], "one")
    if pred.op == ">":
        return PredProgram([a("lt", enc(pred.value), True)], [False], "one")
    if pred.op == ">=":
        return PredProgram([a("lt", enc(pred.value))], [True], "one")
    if pred.op == "<=":
        return PredProgram([a("lt", enc(pred.value), True)], [True], "one")
    if pred.op == "between":
        lo, hi = enc(pred.value[0]), enc(pred.value[1])
        # between = ge * le = (1 - LT(x-lo)) * (1 - LT(hi-x))
        return PredProgram([a("lt", lo), a("lt", hi, True)], [True, True], "mul")
    if pred.op == "in":
        if not pred.value:
            return PredProgram([], [], "zero", table=tname, col=pred.col)
        atoms = [a("eq", enc(v)) for v in pred.value]
        return PredProgram(atoms, [False] * len(atoms), "sum")
    raise ValueError(pred.op)


# ---------------------------------------------------------------------------
# Lowered mask tree.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MaskNode:
    kind: str                     # 'pred' | 'and' | 'or' | 'not' | 'translated'
    table: str = ""
    pred: PredProgram | None = None
    children: list = dataclasses.field(default_factory=list)
    hop: JoinHop | None = None
    # scheduler annotation: ct-ct mask multiplies applied to this node's
    # result before the aggregation injection point (drives i*/ensure_levels)
    downstream_muls: int = 0

    def atoms(self) -> list:
        out = list(self.pred.atoms) if self.pred is not None else []
        for c in self.children:
            out.extend(c.atoms())
        return out

    def clone(self) -> "MaskNode":
        """Structural deep copy (pred/hop are shared read-only): lets
        plan-mutation tooling graft a subtree into several positions
        without aliasing the per-position scheduler annotations."""
        return MaskNode(self.kind, self.table, self.pred,
                        [c.clone() for c in self.children], self.hop,
                        self.downstream_muls)

    def atom_needs(self) -> list:
        """(atom, need_levels) pairs for the whole subtree: how many ct-ct
        multiplications each atom's mask must absorb downstream — the
        node's annotated products plus the predicate's own combiner
        (BETWEEN multiplies its legs before leaving the predicate).
        Drives noise-aware WorkloadCache admission."""
        out = []
        if self.pred is not None:
            extra = (len(self.pred.atoms) - 1
                     if self.pred.combine == "mul" else 0)
            for a in self.pred.atoms:
                out.append((a, self.downstream_muls + extra))
        for c in self.children:
            out.extend(c.atom_needs())
        return out


def compile_mask(db, table: EncryptedTable, expr) -> MaskNode:
    """Recursively lower a MaskExpr over `table` into a MaskNode tree."""
    if isinstance(expr, Pred):
        return MaskNode("pred", table.name, pred=compile_pred(table, expr))
    if isinstance(expr, Not):
        return MaskNode("not", table.name,
                        children=[compile_mask(db, table, expr.child)])
    if isinstance(expr, Translated):
        parent = db.tables[expr.hop.parent]
        return MaskNode("translated", table.name, hop=expr.hop,
                        children=[compile_mask(db, parent, expr.expr)])
    kids = [compile_mask(db, table, c) for c in expr.children]
    return MaskNode("and" if isinstance(expr, And) else "or", table.name,
                    children=kids)


def annotate_downstream(node: MaskNode, above: int) -> None:
    """Scheduler pass: record, per node, how many ct-ct mask products sit
    between it and the aggregation injection point (`above` counts the
    combine layers of its ancestors plus the final R3 injection).  Used
    to size planned refreshes with the §4.3.2 i* rule."""
    node.downstream_muls = above
    if node.kind in ("and", "or"):
        layers = math.ceil(math.log2(max(len(node.children), 2)))
        for c in node.children:
            annotate_downstream(c, above + layers)
    elif node.kind == "not":
        annotate_downstream(node.children[0], above)
    elif node.kind == "translated":
        # the parent-side subtree feeds the broadcast bit: one plaintext
        # multiply (broadcast) + one ct-ct (EQ x bit) before rejoining.
        annotate_downstream(node.children[0], above + 2)


# ---------------------------------------------------------------------------
# Fused atom evaluation (CSE + cross-column batching).
# ---------------------------------------------------------------------------

# Default admission requirement when a consumer's downstream product
# count is unknown: one combine layer + the R3 injection.
DEFAULT_NEED_LEVELS = 2


class AtomEvaluator:
    """Evaluates CmpAtoms against a backend with CSE and circuit fusion.

    `cache` is a WorkloadCache (engine/workload.py) mapping atom.key ->
    mask block entries; shared planner-wide (and, for workload batches,
    across planners), so group-by EQ masks, sort passes, repeated
    predicates and repeated *queries* all hit it.  Every lookup goes
    through the cache's noise-aware admission: the consumer's
    `need_levels` (downstream ct-ct products) is compared against the
    entry's remaining noise budget, so a mask cached by a shallow plan is
    refreshed (charged + counted) or re-derived before a deeper plan may
    consume it — never served blind.
    `fuse=True` stacks every pending atom of one circuit kind into a
    single batched call (cross-mask batching); `fuse=False` evaluates
    atom-at-a-time (each still column-batched over its own blocks).
    `shard_ctx` (engine/sharded.py) shards the stacked launches over the
    mesh data axis: flush() activates it on the backend so every fused
    circuit batch pads/places its lanes across the shards.
    """

    def __init__(self, db, bk, cache=None, fuse: bool = True, shard_ctx=None):
        from .workload import WorkloadCache
        self.db = db
        self.bk = bk
        # No shared cache (share_masks off): a private throwaway store —
        # CSE within this evaluator only, nothing outlives it.
        self.cache = cache if cache is not None else WorkloadCache()
        self.fuse = fuse
        self.shard_ctx = shard_ctx
        self._pending: dict[str, list] = {"eq": [], "lt": []}

    # ------------------------------------------------------------- intake
    def request(self, atom: CmpAtom,
                need_levels: int = DEFAULT_NEED_LEVELS) -> None:
        if self.cache.usable(self.bk, atom, need_levels):
            return
        pend = self._pending[atom.circuit]
        # Unfused mode models the pre-DAG schedule: no sharing at all,
        # duplicate occurrences re-run their circuits.
        if not self.fuse or all(atom.key != p.key for p in pend):
            pend.append(atom)

    def request_tree(self, node: MaskNode) -> None:
        for atom, need in node.atom_needs():
            self.request(atom, need)

    # --------------------------------------------------------------- eval
    def _z_blocks(self, atom: CmpAtom) -> list:
        """The cheap affine shift, column-batched: same op charges as the
        sub_scalar / sub_from_scalar / sub prelude of compare.py."""
        bk = self.bk
        table = self.db.tables[atom.table]
        blocks = table.col(atom.col).blocks
        x = bk.stack_blocks(blocks) if len(blocks) > 1 else blocks[0]
        if atom.rhs is not None:
            rblocks = table.col(atom.rhs).blocks
            y = bk.stack_blocks(rblocks) if len(rblocks) > 1 else rblocks[0]
            z = bk.sub(y, x) if atom.flip else bk.sub(x, y)
        elif atom.flip:
            z = bk.sub_from_scalar(atom.const, x)
        else:
            z = bk.sub_scalar(x, atom.const)
        return bk.unstack_blocks(z) if len(blocks) > 1 else [z]

    def _circuit(self, kind: str, x):
        return cmp.eq_zero(self.bk, x) if kind == "eq" else cmp.lt_zero(self.bk, x)

    def flush(self) -> None:
        """Run every pending circuit.  With fusion, all atoms of a kind
        share ONE stacked launch; op_log still charges one logical eq/cmp
        per atom so the baseline cost models see identical counts.
        Under a shard context the stacked launch is padded/placed over
        the mesh data axis (activation is reentrant, so flushes nested
        inside an already-activated executor run are no-ops here)."""
        bk = self.bk
        from .sharded import activate
        with activate(bk, self.shard_ctx):
            self._flush_inner()

    def _flush_inner(self) -> None:
        bk = self.bk
        for kind, atoms in self._pending.items():
            if not atoms:
                continue
            if not self.fuse or len(atoms) == 1:
                for atom in atoms:
                    zs = self._z_blocks(atom)
                    x = bk.stack_blocks(zs) if len(zs) > 1 else zs[0]
                    out = self._circuit(kind, x)
                    outs = bk.unstack_blocks(out) if len(zs) > 1 else [out]
                    self.cache.insert(bk, atom, outs)
                self._pending[kind] = []
                continue
            per_atom = [(atom, self._z_blocks(atom)) for atom in atoms]
            all_blocks = [b for _, zs in per_atom for b in zs]
            if len(all_blocks) == 1:
                out_blocks = [self._circuit(kind, all_blocks[0])]
            else:
                out = self._circuit(kind, bk.stack_blocks(all_blocks))
                out_blocks = bk.unstack_blocks(out)
            if hasattr(bk, "op_log"):     # one logical circuit per atom
                bk.op_log["eq" if kind == "eq" else "cmp"] += len(atoms) - 1
            i = 0
            for atom, zs in per_atom:
                self.cache.insert(bk, atom, out_blocks[i : i + len(zs)])
                i += len(zs)
            self._pending[kind] = []

    def get(self, atom: CmpAtom,
            need_levels: int = DEFAULT_NEED_LEVELS) -> list:
        """Fetch an atom's mask through noise-aware admission: a cached
        entry is served only if its blocks can still absorb `need_levels`
        products (or as much as a fresh derivation could); otherwise the
        cache refreshes it at admission or drops it for re-derivation."""
        blocks = self.cache.serve(self.bk, atom, need_levels)
        if blocks is None:
            self.request(atom, need_levels)
            self.flush()
            blocks = self.cache.serve(self.bk, atom, need_levels)
        return blocks

    # ------------------------------------------------- group-by EQ masks
    def eq_masks(self, table: EncryptedTable, col: str, values,
                 need_levels: int = DEFAULT_NEED_LEVELS) -> list:
        """Memoized per-value EQ masks (GROUP BY / ORDER BY dictionary
        enumeration), fused into one launch per flush."""
        atoms = [CmpAtom(table.name, col, "eq", int(v)) for v in values]
        for atom in atoms:
            self.request(atom, need_levels)
        self.flush()
        return [(int(v), self.get(atom, need_levels))
                for v, atom in zip(values, atoms)]


# ---------------------------------------------------------------------------
# Mask-tree execution (optimized regime: R1 isolation + R2 balanced trees).
# ---------------------------------------------------------------------------

def run_mask_node(node: MaskNode, ev: AtomEvaluator, planner) -> list:
    """Execute a lowered tree bottom-up against pre-evaluated atoms.
    Combiners reproduce the legacy optimized circuits exactly (balanced
    mul/or trees, batched negation)."""
    from . import ops
    bk = ev.bk
    if node.kind == "pred":
        return _run_pred(node.pred, ev, node.downstream_muls)
    if node.kind == "not":
        return ops.not_mask(bk, run_mask_node(node.children[0], ev, planner))
    if node.kind == "translated":
        parent_mask = run_mask_node(node.children[0], ev, planner)
        assert len(parent_mask) == 1, "translated: single-block parent"
        child = ev.db.tables[node.hop.child]
        nparent = ev.db.tables[node.hop.parent].nrows
        need = planner.translate_levels(node.downstream_muls)
        return ops.translate_mask_down(bk, parent_mask[0], child, node.hop.fk,
                                       nparent, need_levels=need,
                                       eq_cache=ev.cache)
    kids = [run_mask_node(c, ev, planner) for c in node.children]
    # Noise-aware combine ordering: pair shallow masks first so the deep
    # legs (translated joins) enter the balanced tree as late as possible
    # — same depth, strictly less noise than arbitrary pairing.
    kids.sort(key=lambda m: bk.depth(m[0]))
    if node.kind == "and":
        return ops.and_masks(bk, kids)
    return ops.or_masks(bk, kids)


def _run_pred(prog: PredProgram, ev: AtomEvaluator,
              downstream_muls: int = DEFAULT_NEED_LEVELS) -> list:
    from . import ops
    bk = ev.bk
    if prog.combine == "zero":                      # empty IN set: all-zero
        blocks = ev.db.tables[prog.table].col(prog.col).blocks
        x, batched = ops._stacked(bk, blocks)
        return ops._unstacked(bk, bk.mul_scalar(x, 0), batched)
    # BETWEEN's legs absorb the in-predicate products on top of the
    # tree-level downstream count (mirrors MaskNode.atom_needs).
    need = downstream_muls + (len(prog.atoms) - 1 if prog.combine == "mul" else 0)
    parts = []
    for atom, neg in zip(prog.atoms, prog.negs):
        m = ev.get(atom, need)
        parts.append(ops.not_mask(bk, m) if neg else m)
    if prog.combine == "one":
        return parts[0]
    if prog.combine == "mul":                       # BETWEEN
        out = parts[0]
        for nxt in parts[1:]:
            out = ops.mul_lists(bk, out, nxt)
        return out
    # 'sum' — IN: balanced addition tree over stacked masks (Eq. 6).
    nblocks = len(parts[0])
    stacked = ([p[0] for p in parts] if nblocks == 1
               else [bk.stack_blocks(p) for p in parts])
    out = cmp.add_tree(bk, stacked)
    return bk.unstack_blocks(out) if nblocks > 1 else [out]
