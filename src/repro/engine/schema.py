"""Schema, type encodings and dictionary compression (paper §2.1.5).

BFV operates on Z_t, so every SQL type maps to small integers:
  int      — raw (must fit < t/2 so column-vs-column subtraction stays in
             the centered half-range the LT circuit decodes)
  decimal  — fixed point: value * 10^frac_digits, tracked via `scale`
  date     — days since 1992-01-01 (TPC-H epoch), +1 so 0 stays the pad
  str      — dictionary encoding: sequential ids 1..D (0 = padding);
             dictionary sizes are public metadata (paper §3 leakage L)
  flag     — small categorical, stored like str

Value domains are validated against t at load: the paper's evaluation
stores 16-bit integers under t=65537 (Fig. 7), and the LT circuit needs
|x - y| < t/2; we enforce both.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt

import numpy as np

EPOCH = _dt.date(1992, 1, 1)
PAD = 0  # slot-padding sentinel, outside every encoded domain


def date_to_int(d: str | _dt.date) -> int:
    if isinstance(d, str):
        d = _dt.date.fromisoformat(d)
    return (d - EPOCH).days + 1


@dataclasses.dataclass
class ColumnSpec:
    name: str
    kind: str                      # int | decimal | date | str | flag
    scale: int = 1                 # decimal fixed-point multiplier
    dictionary: dict[str, int] | None = None   # str -> id (built at load)

    def encode(self, values) -> np.ndarray:
        if self.kind == "str" or self.kind == "flag":
            if self.dictionary is None:
                uniq = sorted(set(values))
                self.dictionary = {v: i + 1 for i, v in enumerate(uniq)}
            return np.array([self.dictionary[v] for v in values], dtype=np.int64)
        if self.kind == "date":
            vals = np.asarray(values)
            if np.issubdtype(vals.dtype, np.integer):
                return vals.astype(np.int64)      # already day offsets
            return np.array([date_to_int(v) for v in values], dtype=np.int64)
        if self.kind == "decimal":
            # float64 product, rounded before the cast: scaled decimals
            # stay < t = 2^16 < 2^53 — exact int64.
            return np.round(np.asarray(values, dtype=np.float64) * self.scale).astype(np.int64)
        return np.asarray(values, dtype=np.int64)

    def encode_scalar(self, v) -> int:
        if self.kind in ("str", "flag"):
            assert self.dictionary is not None, f"{self.name}: dictionary not built"
            # Constants absent from the data map to an id that matches no
            # row (ids are 1..D, pads are 0) — the predicate is just empty.
            return self.dictionary.get(v, len(self.dictionary) + 1)
        if self.kind == "date":
            return int(v) if isinstance(v, (int, np.integer)) else date_to_int(v)
        if self.kind == "decimal":
            return int(round(float(v) * self.scale))
        return int(v)

    def decode(self, ids: np.ndarray):
        if self.kind in ("str", "flag") and self.dictionary is not None:
            rev = {i: s for s, i in self.dictionary.items()}
            return [rev.get(int(x), "<pad>") for x in ids]
        if self.kind == "decimal":
            return np.asarray(ids, dtype=np.float64) / self.scale
        return ids

    @property
    def domain_size(self) -> int | None:
        return len(self.dictionary) if self.dictionary is not None else None


@dataclasses.dataclass
class TableSchema:
    name: str
    columns: list[ColumnSpec]

    def col(self, name: str) -> ColumnSpec:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"{self.name}.{name}")


def validate_domain(arr: np.ndarray, t: int, name: str = "") -> None:
    """All engine values must stay in [0, t/2) so centered differences
    decode correctly in the comparison circuits."""
    mx, mn = int(arr.max(initial=0)), int(arr.min(initial=0))
    if mn < 0 or mx >= t // 2:
        raise ValueError(f"column {name}: domain [{mn},{mx}] outside [0, {t//2})")
