"""Logical plan nodes and the multiplicative-depth model (paper Table 3).

The planner treats multiplicative depth as the primary cost (§4.3).  Every
node can report its depth under the *optimized* regime (independent
subgraphs, balanced trees) and the *unoptimized* regime (sequential
pipeline with predicate pushdown — masks applied to columns as soon as
they are produced, so later comparisons run on already-deepened inputs).

Depth table (t = plaintext prime, n = slots):
  equality            ceil(log2(t-1))            square chain
  range (<,<=,>,>=)   ceil(log2(t-1)) + 1        sgn interpolant via BSGS
  between             range + 1                  product of two masks
  in                  equality                   balanced sum of EQs
  aggregation         ~log(n)/t (rotations)      effectively 0 mul-depth
  join                equality + 1               EQ mask x attribute
  group by/order by   equality                   one EQ mask per value
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


def eq_depth(t: int) -> int:
    return math.ceil(math.log2(t - 1))


def lt_depth(t: int) -> int:
    return eq_depth(t) + 1


@dataclasses.dataclass(frozen=True)
class Pred:
    """A comparison: col <op> value, or col <op> rhs_col (column form)."""

    col: str
    op: str                       # = | != | < | <= | > | >= | between | in
    value: Any = None
    rhs_col: str | None = None

    def depth(self, t: int) -> int:
        if self.op in ("=", "!=", "in"):
            return eq_depth(t)
        if self.op == "between":
            return lt_depth(t) + 1
        return lt_depth(t)


@dataclasses.dataclass(frozen=True)
class And:
    children: tuple

    def depth(self, t: int, optimized: bool = True) -> int:
        ds = [child_depth(c, t, optimized) for c in self.children]
        if optimized:
            # R2 independent evaluation + balanced product tree.
            return max(ds) + math.ceil(math.log2(len(ds))) if len(ds) > 1 else ds[0]
        # Sequential: each conjunct multiplied in one after another.
        return max(ds) + len(ds) - 1


@dataclasses.dataclass(frozen=True)
class Or:
    children: tuple

    def depth(self, t: int, optimized: bool = True) -> int:
        ds = [child_depth(c, t, optimized) for c in self.children]
        if optimized:
            return max(ds) + math.ceil(math.log2(len(ds))) if len(ds) > 1 else ds[0]
        return max(ds) + len(ds) - 1


@dataclasses.dataclass(frozen=True)
class Not:
    child: Any

    def depth(self, t: int, optimized: bool = True) -> int:
        return child_depth(self.child, t, optimized)


def child_depth(c, t: int, optimized: bool = True) -> int:
    if isinstance(c, Pred):
        return c.depth(t)
    return c.depth(t, optimized)


MaskExpr = Any  # Pred | And | Or | Not | Translated (defined below)


@dataclasses.dataclass(frozen=True)
class Translated:
    """A mask evaluated on `hop.parent` and pushed down to the child
    table through the FK (Fig. 2 Extract+Broadcast+EQ).  This is the
    *executable* form of a filtering join: Q19's per-branch part masks
    are `Translated(JoinHop(part -> lineitem), <part predicate tree>)`
    nodes sitting inside the fact table's WHERE tree.

    Depth: the EQ on the fk column meets the broadcast parent bit
    (parent depth + 1 plaintext multiply) in one ct-ct product — the
    same recurrence as a JoinHop with a parent_filter."""

    hop: "JoinHop"
    expr: Any                     # MaskExpr over hop.parent's columns

    def depth(self, t: int, optimized: bool = True) -> int:
        return max(eq_depth(t), child_depth(self.expr, t, optimized) + 1) + 1


@dataclasses.dataclass(frozen=True)
class AuxMask:
    """A named auxiliary fact-table mask: `expr` evaluated over
    `hop.parent`, translated down through `hop.fk`.  Aggregates can
    partition on it (Q12's high/low priority line counts) without the
    mask participating in the WHERE conjunction."""

    name: str
    hop: "JoinHop"
    expr: Any                     # MaskExpr over hop.parent's columns


@dataclasses.dataclass(frozen=True)
class Factor:
    """(add + mult * col): the affine factors appearing in aggregates,
    e.g. extendedprice * (1 - discount) with discount scaled by 100 is
    Factor('l_extendedprice') * Factor('l_discount', mult=-1, add=100)."""

    col: str | None = None
    mult: int = 1
    add: int = 0


@dataclasses.dataclass(frozen=True)
class Agg:
    kind: str                     # sum | count | avg
    factors: tuple = ()           # product of Factors (empty for count)
    name: str = ""
    partition: str | None = None  # AuxMask name this aggregate is CASEd on
    negated: bool = False         # count the complement of the partition

    def mul_depth(self) -> int:
        """ct-ct multiplies needed to form the aggregate's expression."""
        ncols = sum(1 for f in self.factors if f.col is not None)
        return max(0, ncols - 1)


@dataclasses.dataclass(frozen=True)
class JoinHop:
    """FK -> PK hop: child.fk references parent.key (dense 1..|parent|)."""

    parent: str
    fk: str
    child: str
    parent_filter: MaskExpr | None = None

    def depth(self, t: int, incoming: int = 0) -> int:
        # EQ on the fk column + multiply by the (broadcast) parent mask.
        return max(eq_depth(t), incoming) + 1


@dataclasses.dataclass
class QueryPlan:
    """Declarative description of one benchmark query: enough structure
    for the depth/cost model; execution is composed from the same pieces
    by engine/queries.py."""

    name: str
    fact: str
    where: MaskExpr | None = None
    hops: tuple = ()              # JoinHops, outermost parent first
    group_by: str | None = None   # column on fact (or translated) domain
    group_domain: int = 0
    aggs: tuple = ()
    order_by: str | None = None
    correlated: bool = False      # Q4/Q17-style subquery (extra LT stage)
    aux_masks: tuple = ()         # AuxMasks aggregates may partition on

    def describe(self) -> str:
        """One-line structural summary (verifier CLI / report headers)."""
        bits = [f"fact={self.fact}"]
        if self.where is not None:
            bits.append("where")
        if self.hops:
            bits.append(f"hops={len(self.hops)}")
        if self.group_by:
            bits.append(f"group_by={self.group_by}")
        if self.aux_masks:
            bits.append(f"aux={len(self.aux_masks)}")
        if self.correlated:
            bits.append("correlated")
        bits.append(f"aggs={len(self.aggs)}")
        return f"{self.name}({', '.join(bits)})"

    # ---- Table-3 depth model ------------------------------------------
    def mask_depth(self, t: int, optimized: bool) -> int:
        parts = []
        if self.where is not None:
            parts.append(child_depth(self.where, t, optimized))
        d_chain = 0
        for hop in self.hops:
            base = eq_depth(t)
            if hop.parent_filter is not None:
                base = max(base, child_depth(hop.parent_filter, t, optimized) + 1)
            if optimized:
                d_chain = max(d_chain, base) + 1
            else:
                # pushdown: the EQ runs on an already-masked column.
                d_chain = d_chain + base + 1
        if d_chain:
            parts.append(d_chain)
        if self.correlated:
            parts.append(eq_depth(t) + lt_depth(t) + 2)
        if not parts:
            return 0
        if optimized:
            return max(parts) + (math.ceil(math.log2(len(parts))) if len(parts) > 1 else 0)
        return max(parts) + len(parts) - 1

    def total_depth(self, t: int, optimized: bool = True) -> int:
        d_mask = self.mask_depth(t, optimized)
        d_group = eq_depth(t) if self.group_by else 0
        d_agg = max((a.mul_depth() for a in self.aggs), default=0)
        if optimized:
            # R3 late injection: group mask, where mask and the aggregate
            # expression meet in one balanced product.
            legs = [d for d in (d_mask, d_group) if d]
            inject = (max(legs) + len(legs) - 1) if legs else 0
            return inject + d_agg + 1
        # Unoptimized: group-by EQ runs on masked columns, aggregates on
        # masked expressions.
        return d_mask + d_group + d_agg + 1
