"""Compiled-DAG query execution (DESIGN.md §7).

`run_via_plan(planner, plan)` executes a declarative `QueryPlan` end to
end: the logical WHERE/aux/group structure is lowered through
engine/physical.py into atom + combine + translate + aggregate stages,
the scheduler fuses distinct comparison circuits into cross-column
batched launches (optimized regime), reuses mask subgraphs through the
planner's CSE cache, and places planned refreshes for translated masks
with the §4.3.2 i* rule.  The same plan runs in both regimes:

  optimized    R1 atom isolation + fused circuit launches + R2 balanced
               combine trees + R3 late injection at the aggregate.
  unoptimized  the classical pipeline: sequential mask chains, joins
               over already-filtered FK columns, group EQs on masked
               columns — the Fig. 3(a) baseline, unfused.

Every execution produces an `ExecReport` (the recorded op history) that
is checked against the planner's `PlanReport`: measured multiplicative
depth must stay within a small constant of the Table-3 prediction, and
refresh events may only occur when the model predicted bootstraps.  The
legacy `run_qN` bodies in engine/queries.py are kept verbatim as parity
oracles — `run_via_plan` must reproduce their decrypted output exactly.
"""
from __future__ import annotations

import dataclasses
import itertools

from . import ops
from .physical import (CmpAtom, annotate_downstream, compile_mask,
                       run_mask_node)
from .plan import And, Pred, QueryPlan

# Tolerances between the Table-3 depth model and the executed history:
# the model counts only ct-ct multiplies, while measured depth includes
# plaintext-multiply steps (validity, broadcasts) and BSGS slack.
DEPTH_SLACK_OVER = 3      # measured may exceed predicted by at most this
DEPTH_SLACK_UNDER = 7     # optimized predictions may overshoot by this


@dataclasses.dataclass
class ExecReport:
    """Recorded op history of one compiled-DAG execution."""

    name: str
    optimized: bool
    predicted_depth: int
    predicted_refreshes: int
    budget_levels: int
    measured_depth: int = 0
    refreshes: int = 0
    launches: int = 0
    muls: int = 0
    history: list = dataclasses.field(default_factory=list)

    def record(self, label: str, before, after) -> None:
        self.history.append({
            "stage": label,
            "mul": after.mul - before.mul,
            "add": after.add - before.add,
            "rotate": after.rotate - before.rotate,
            "launches": after.launches - before.launches,
            "refresh": after.refresh - before.refresh,
            "max_depth": after.max_depth,
        })

    def validate(self) -> None:
        """Assert the §4.3 noise model against the executed history."""
        assert self.measured_depth <= self.predicted_depth + DEPTH_SLACK_OVER, (
            f"{self.name}: executed depth {self.measured_depth} exceeds "
            f"predicted {self.predicted_depth} (+{DEPTH_SLACK_OVER})")
        if self.optimized:
            assert self.predicted_depth <= self.measured_depth + DEPTH_SLACK_UNDER, (
                f"{self.name}: prediction {self.predicted_depth} overshoots "
                f"measured {self.measured_depth} (+{DEPTH_SLACK_UNDER})")
            if self.predicted_refreshes == 0:
                assert self.refreshes == 0, (
                    f"{self.name}: plan predicted refresh-free but executor "
                    f"paid {self.refreshes} refreshes")
        if self.refreshes > 0:
            assert self.predicted_refreshes > 0, (
                f"{self.name}: {self.refreshes} refreshes but the model "
                f"predicted none")


class Executor:
    """Runs one lowered QueryPlan against the planner's backend."""

    def __init__(self, planner):
        self.pl = planner
        self.bk = planner.bk
        self.db = planner.db
        self.report: ExecReport | None = None

    # ------------------------------------------------------------ public
    def run(self, plan: QueryPlan, validate: bool = True) -> dict:
        if plan.correlated:
            raise NotImplementedError(
                f"{plan.name}: correlated subqueries are not lowered yet")
        pl, bk = self.pl, self.bk
        pr = pl.report(plan)
        self.report = ExecReport(plan.name, pl.optimized, pr.predicted_depth,
                                 pr.predicted_refreshes, pr.budget_levels)
        start = bk.stats.clone()
        prior_max = bk.stats.max_depth
        bk.stats.max_depth = 0
        try:
            out = self._execute(plan)
        finally:
            end = bk.stats.clone()
            self.report.measured_depth = bk.stats.max_depth
            self.report.refreshes = end.refresh - start.refresh
            self.report.launches = end.launches - start.launches
            self.report.muls = end.mul - start.mul
            bk.stats.max_depth = max(prior_max, bk.stats.max_depth)
        if validate:
            self.report.validate()
        return out

    # ------------------------------------------------------- compilation
    def _split_group_in(self, where, group_cols):
        """Group pushdown: an IN predicate on the (single) group column
        defines the group domain and leaves the WHERE tree — the group
        enumeration already restricts to exactly those values."""
        group_values: dict[str, list] = {}
        if len(group_cols) != 1 or where is None:
            return where, group_values
        col = group_cols[0]
        is_group_in = lambda e: isinstance(e, Pred) and e.col == col and e.op == "in"
        if is_group_in(where):
            return None, {col: list(where.value)}
        if isinstance(where, And):
            hit = [c for c in where.children if is_group_in(c)]
            if hit:
                # Absorb exactly one IN into the group enumeration; any
                # further predicates on the group column stay in WHERE.
                kept = [c for c in where.children if c is not hit[0]]
                group_values[col] = list(hit[0].value)
                if not kept:
                    where = None
                elif len(kept) == 1:
                    where = kept[0]
                else:
                    where = And(tuple(kept))
        return where, group_values

    def _group_items(self, fact, group_cols, group_values):
        """Per group column: [(name, encoded id), ...] in output order.
        Pushed-down values encode with predicate semantics (constants
        absent from the data map to a no-match id -> empty group)."""
        per_col = []
        for col in group_cols:
            spec = fact.schema.col(col)
            if col in group_values:
                per_col.append([(v, spec.encode_scalar(v))
                                for v in group_values[col]])
            elif spec.dictionary is not None:
                per_col.append(sorted(spec.dictionary.items()))
            else:
                raise NotImplementedError(
                    f"group_by {col}: no dictionary and no IN predicate to "
                    f"enumerate the domain from")
        return per_col

    # --------------------------------------------------------- execution
    def _execute(self, plan: QueryPlan) -> dict:
        pl, bk, db = self.pl, self.bk, self.db
        fact = db.tables[plan.fact]
        stats = bk.stats
        group_cols = ([c.strip() for c in plan.group_by.split(",")]
                      if plan.group_by else [])
        where_expr, group_values = self._split_group_in(plan.where, group_cols)
        per_col_items = self._group_items(fact, group_cols, group_values)

        where_node = (compile_mask(db, fact, where_expr)
                      if where_expr is not None else None)
        aux_nodes = {a.name: (a, compile_mask(db, db.tables[a.hop.parent], a.expr))
                     for a in plan.aux_masks}
        inject_layers = (2 if group_cols else 1) \
            + max((a.mul_depth() for a in plan.aggs), default=0)
        if where_node is not None:
            annotate_downstream(where_node, inject_layers)
        for _, node in aux_nodes.values():
            annotate_downstream(node, 2)   # AND with base + R3 injection

        if pl.optimized:
            # Stage 1 — fused atom evaluation: every distinct comparison
            # circuit in the query (WHERE + aux + group EQs) is requested
            # up front and evaluated in one stacked launch per shape.
            ev = pl.evaluator()
            snap = stats.clone()
            if where_node is not None:
                ev.request_tree(where_node)
            for _, node in aux_nodes.values():
                ev.request_tree(node)
            for col, items in zip(group_cols, per_col_items):
                for _name, vid in items:
                    ev.request(CmpAtom(fact.name, col, "eq", int(vid)))
            ev.flush()
            self.report.record("atoms[fused]", snap, stats.clone())

            snap = stats.clone()
            where = (run_mask_node(where_node, ev, pl)
                     if where_node is not None else None)
            self.report.record("where", snap, stats.clone())
            aux = {}
            for name, (a, node) in aux_nodes.items():
                snap = stats.clone()
                aux[name] = self._translate_aux(a, node, ev, None)
                self.report.record(f"aux:{name}", snap, stats.clone())
            gmasks = {
                col: dict(pl.group_masks(fact, col, [vid for _n, vid in items]))
                for col, items in zip(group_cols, per_col_items)
            }
        else:
            # Classical pipeline: sequential chains, no fusion, joins over
            # filtered FK columns, raw group EQs combined after the WHERE.
            snap = stats.clone()
            where = (pl.where_mask(fact, where_expr)
                     if where_expr is not None else None)
            self.report.record("where[seq]", snap, stats.clone())
            aux = {}
            for name, (a, node) in aux_nodes.items():
                snap = stats.clone()
                fk_ov = (ops.mask_columns(bk, fact.col(a.hop.fk).blocks, where)
                         if where is not None else None)
                aux[name] = self._translate_aux(a, node, None, fk_ov)
                self.report.record(f"aux:{name}[pushdown]", snap, stats.clone())
            gmasks = {
                col: dict(ops.group_masks(bk, fact, col,
                                          [vid for _n, vid in items]))
                for col, items in zip(group_cols, per_col_items)
            }

        snap = stats.clone()
        out = (self._grouped(plan, fact, per_col_items, gmasks, where, aux)
               if group_cols else self._ungrouped(plan, fact, where))
        self.report.record("aggregate", snap, stats.clone())
        return out

    def _translate_aux(self, a, node, ev, fk_override):
        """Aux mask: parent-table subtree -> translated fact mask."""
        pl, bk, db = self.pl, self.bk, self.db
        if ev is not None:
            parent_mask = run_mask_node(node, ev, pl)
        else:
            parent_mask = pl.where_mask(db.tables[a.hop.parent], a.expr)
        assert len(parent_mask) == 1, "aux translate: single-block parent"
        need = pl.translate_levels(node.downstream_muls)
        return ops.translate_mask_down(bk, parent_mask[0], db.tables[a.hop.child],
                                       a.hop.fk, db.tables[a.hop.parent].nrows,
                                       fk_override=fk_override, need_levels=need)

    # ------------------------------------------------------- aggregation
    def _dec(self, ct):
        return int(self.bk.decrypt(ct)[0])

    def _dec_agg(self, agg, r):
        if agg.kind == "avg":
            return (self._dec(r[0]), self._dec(r[1]))
        return self._dec(r)

    def _ungrouped(self, plan, fact, where) -> dict:
        pl = self.pl
        return {agg.name: self._dec_agg(agg, pl.aggregate(fact, agg, where))
                for agg in plan.aggs}

    def _grouped(self, plan, fact, per_col_items, gmasks, where, aux) -> dict:
        pl, bk = self.pl, self.bk
        out = {}
        for combo in itertools.product(*per_col_items):
            key = combo[0][0] if len(combo) == 1 else tuple(n for n, _ in combo)
            gm_lists = [gmasks[col][vid]
                        for col, (_n, vid) in zip(gmasks, combo)]
            legs = gm_lists + ([where] if where is not None else [])
            if pl.optimized:
                base = ops.and_masks(bk, legs) if len(legs) > 1 else legs[0]
            else:
                seq = ([where] + gm_lists) if where is not None else gm_lists
                base = ops.and_masks_seq(bk, seq) if len(seq) > 1 else seq[0]
            base = ops.apply_validity(bk, base, fact)
            row, parts = {}, {}
            for agg in plan.aggs:
                if agg.partition is None:
                    row[agg.name] = self._dec_agg(
                        agg, pl._agg_with_mask(fact, agg, base))
                    continue
                if agg.partition not in parts:
                    am = aux[agg.partition]
                    parts[agg.partition] = (
                        ops.and_masks(bk, [base, am]) if pl.optimized
                        else ops.and_masks_seq(bk, [base, am]))
                hit = parts[agg.partition]
                m = ([bk.sub(b, h) for b, h in zip(base, hit)]
                     if agg.negated else hit)      # complement = base - hit
                row[agg.name] = self._dec_agg(
                    agg, pl._agg_with_mask(fact, agg, m))
            out[key] = row
        return out


def run_via_plan(planner, plan: QueryPlan, validate: bool = True) -> dict:
    """Execute a QueryPlan through the compiled operator DAG.  Returns
    the same decrypted result structure as the legacy `run_qN` body."""
    return Executor(planner).run(plan, validate=validate)
