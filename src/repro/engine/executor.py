"""Compiled-DAG query execution (DESIGN.md §7).

`run_via_plan(planner, plan)` executes a declarative `QueryPlan` end to
end: the logical WHERE/aux/group structure is lowered through
engine/physical.py into atom + combine + translate + aggregate stages,
the scheduler fuses distinct comparison circuits into cross-column
batched launches (optimized regime), reuses mask subgraphs through the
planner's CSE cache, and places planned refreshes for translated masks
with the §4.3.2 i* rule.  The same plan runs in both regimes:

  optimized    R1 atom isolation + fused circuit launches + R2 balanced
               combine trees + R3 late injection at the aggregate.
  unoptimized  the classical pipeline: sequential mask chains, joins
               over already-filtered FK columns, group EQs on masked
               columns — the Fig. 3(a) baseline, unfused.

Every execution produces an `ExecReport` (the recorded op history) that
is checked against the planner's `PlanReport`: measured multiplicative
depth must stay within a small constant of the Table-3 prediction, and
refresh events may only occur when the model predicted bootstraps.  The
legacy `run_qN` bodies in engine/queries.py are kept verbatim as parity
oracles — `run_via_plan` must reproduce their decrypted output exactly.

Fault tolerance (DESIGN.md §9): execution is staged through a
`StageCheckpoint` — materialized mask blocks are recorded at every DAG
stage boundary (atoms / where / aux / gmasks), so a `DeviceLossFault`
resumes from the last completed stage on a re-sharded mesh
(`ShardContext.reshard` via `elastic_scan_plan`) instead of from
scratch.  With guards armed (an injected FaultPlan, or
`Planner(guards=True)`), every decrypt boundary runs the headroom check
of runtime/faults.py plus a plaintext sentinel lane, and a
`NoiseOverflowFault` triggers bounded recovery: refresh the
checkpointed masks and retry, then re-derive from base columns, then
fail typed.  A recovered run never validates against the plan model —
its op history spans partial attempts — but must still decrypt
byte-identical to the fault-free run.
"""
from __future__ import annotations

import dataclasses
import itertools

from ..runtime import faults
from . import ops
from .physical import (CmpAtom, annotate_downstream, compile_mask,
                       run_mask_node)
from .plan import And, Pred, QueryPlan

# Tolerances between the Table-3 depth model and the executed history:
# the model counts only ct-ct multiplies, while measured depth includes
# plaintext-multiply steps (validity, broadcasts) and BSGS slack.
DEPTH_SLACK_OVER = 3      # measured may exceed predicted by at most this
DEPTH_SLACK_UNDER = 7     # optimized predictions may overshoot by this

# Bounded recovery (DESIGN §9): one refresh-and-retry, one re-derive
# from base columns, then a typed NoiseOverflowFault.
MAX_OVERFLOW_RETRIES = 2
# Device-loss resumes halve the mesh each time; a handful of attempts
# exhausts any realistic shard count before this trips.
MAX_DEVICE_LOSS_RECOVERIES = 4


@dataclasses.dataclass
class ExecReport:
    """Recorded op history of one compiled-DAG execution."""

    name: str
    optimized: bool
    predicted_depth: int
    predicted_refreshes: int
    budget_levels: int
    measured_depth: int = 0
    refreshes: int = 0
    launches: int = 0
    muls: int = 0
    # Workload-cache accounting for this execution: masks served from
    # earlier runs, and refresh charges paid at cache admission (the
    # noise-aware serve of engine/workload.py).  Admission refreshes are
    # *predicted by construction* — the cache priced them against the
    # consumer's downstream_muls — so validate() nets them out of the
    # plan-model refresh invariants instead of calling them unpredicted.
    cache_hits: int = 0
    cache_admit_refreshes: int = 0
    history: list = dataclasses.field(default_factory=list)
    # Observed noise headroom (bits) at every decrypt boundary, in
    # execution order — the runtime half of the static verifier's
    # soundness cross-check (VerifyReport.crosscheck): the abstract
    # bound must never be tighter than what execution observed.
    decrypt_headrooms: list = dataclasses.field(default_factory=list)
    # Recovery events this execution survived (overflow retries, device
    # -loss resumes, straggler exclusions) — see DESIGN §9.  A run that
    # recovered from overflow/device-loss executed partial attempts, so
    # plan-model validation is skipped for it; the typed-or-identical
    # contract is asserted by the chaos suite instead.
    recoveries: list = dataclasses.field(default_factory=list)

    def record(self, label: str, before, after) -> None:
        self.history.append({
            "stage": label,
            "mul": after.mul - before.mul,
            "add": after.add - before.add,
            "rotate": after.rotate - before.rotate,
            "launches": after.launches - before.launches,
            "refresh": after.refresh - before.refresh,
            "max_depth": after.max_depth,
        })

    def op_history_diff(self) -> str:
        """Expected-vs-observed accounting plus the per-stage history
        table — appended to every validate() assertion so a chaos-test
        failure is diagnosable from the message alone."""
        unplanned = self.refreshes - self.cache_admit_refreshes
        lines = [
            f"op-history diff for {self.name} "
            f"(optimized={self.optimized}):",
            f"  depth     predicted={self.predicted_depth} "
            f"measured={self.measured_depth} budget={self.budget_levels} "
            f"slack=+{DEPTH_SLACK_OVER}/-{DEPTH_SLACK_UNDER}",
            f"  refreshes predicted={self.predicted_refreshes} "
            f"observed={self.refreshes} admit={self.cache_admit_refreshes} "
            f"unplanned={unplanned}",
            f"  launches  {self.launches}  muls {self.muls}  "
            f"cache_hits {self.cache_hits}",
            f"  {'stage':<20} {'mul':>6} {'add':>6} {'rot':>6} "
            f"{'launch':>6} {'refr':>5} {'depth':>5}",
        ]
        for h in self.history:
            lines.append(
                f"  {h['stage']:<20} {h['mul']:>6} {h['add']:>6} "
                f"{h['rotate']:>6} {h['launches']:>6} {h['refresh']:>5} "
                f"{h['max_depth']:>5}")
        for r in self.recoveries:
            lines.append(f"  recovery: {r}")
        return "\n".join(lines)

    def validate(self) -> None:
        """Assert the §4.3 noise model against the executed history.

        Cache-served masks may legally be *fresher* than a cold
        derivation (an earlier plan's planned refresh rejuvenated them in
        place), so the undershoot bound only applies to cold executions;
        and refreshes charged at cache admission are planned by the
        cache's own i*-style sizing, so the plan-model refresh invariants
        apply to the net (unplanned) count."""
        if any(r.get("kind") in ("overflow", "device-loss")
               for r in self.recoveries):
            # Partial attempts make the op history incomparable to the
            # single-pass plan model; the recovery contract (identical
            # result or typed fault) is what holds here.
            return
        diff = "\n" + self.op_history_diff()
        assert self.measured_depth <= self.predicted_depth + DEPTH_SLACK_OVER, (
            f"{self.name}: executed depth {self.measured_depth} exceeds "
            f"predicted {self.predicted_depth} (+{DEPTH_SLACK_OVER})" + diff)
        unplanned = self.refreshes - self.cache_admit_refreshes
        if self.optimized:
            if self.cache_hits == 0:
                assert self.predicted_depth <= self.measured_depth + DEPTH_SLACK_UNDER, (
                    f"{self.name}: prediction {self.predicted_depth} overshoots "
                    f"measured {self.measured_depth} (+{DEPTH_SLACK_UNDER})"
                    + diff)
            if self.predicted_refreshes == 0:
                assert unplanned <= 0, (
                    f"{self.name}: plan predicted refresh-free but executor "
                    f"paid {unplanned} unplanned refreshes "
                    f"({self.refreshes} total, {self.cache_admit_refreshes} "
                    f"at cache admission)" + diff)
        if unplanned > 0:
            assert self.predicted_refreshes > 0, (
                f"{self.name}: {unplanned} unplanned refreshes but the model "
                f"predicted none" + diff)


@dataclasses.dataclass
class StageCheckpoint:
    """Materialized-mask checkpoints at DAG stage boundaries.

    Mid-query recovery state: each completed stage stores its payload
    (the structure the aggregate consumes) plus the flat ciphertext
    handles it materialized.  On device loss the executor re-enters
    `_execute` with the same checkpoint — completed stages return their
    payload instead of re-running, so only work after the last boundary
    repeats on the re-sharded mesh.  On noise overflow `refresh_all`
    rejuvenates every checkpointed block in place (the refresh-and-retry
    arm) and `clear` drops everything (the re-derive-from-base arm).
    """

    done: dict = dataclasses.field(default_factory=dict)
    blocks: dict = dataclasses.field(default_factory=dict)
    resumes: int = 0

    def has(self, stage: str) -> bool:
        return stage in self.done

    def get(self, stage: str):
        return self.done[stage]

    def put(self, stage: str, payload, blocks=()) -> None:
        self.done[stage] = payload
        self.blocks[stage] = [b for b in blocks if b is not None]

    def completed(self) -> list:
        return list(self.done)

    def clear(self) -> None:
        self.done.clear()
        self.blocks.clear()

    def refresh_all(self, bk) -> None:
        """Rejuvenate every checkpointed mask block (client
        re-encryption under NSHEDB's trust model), charged as refreshes
        so recovery cost stays visible in OpStats."""
        seen = set()
        for blocks in self.blocks.values():
            for b in blocks:
                if id(b) in seen:
                    continue
                seen.add(id(b))
                bk._charge_refresh(b, None, "recovery(overflow)")
                bk.refresh_inplace(b)


@dataclasses.dataclass
class CompiledQuery:
    """One QueryPlan lowered to the physical IR, ready to execute:
    annotated mask trees + group enumeration, but no ciphertext touched
    yet.  `run_workload` compiles a whole batch first so every query's
    atoms can fuse into the same stacked launches."""

    plan: QueryPlan
    fact: object
    group_cols: list
    where_expr: object
    group_values: dict
    per_col_items: list
    where_node: object
    aux_nodes: dict
    inject_layers: int


class Executor:
    """Runs one lowered QueryPlan against the planner's backend.

    `evaluator` (optional) shares one AtomEvaluator across executors —
    the workload scheduler passes the batch-wide evaluator so circuits
    fuse between queries; standalone runs build their own."""

    def __init__(self, planner, evaluator=None):
        self.pl = planner
        self.bk = planner.bk
        self.db = planner.db
        self.ev = evaluator
        self.report: ExecReport | None = None
        self._guards = False          # decrypt-boundary guards armed?
        self._sentinel = None         # plaintext sentinel lane (guarded)
        self._verify_report = None    # static VerifyReport of the last run

    # ------------------------------------------------------------ public
    def run(self, plan: QueryPlan, validate: bool = True) -> dict:
        cq = self.compile(plan)
        self._static_verify(cq, mirror_begin_run=True, warm=False)
        if self.pl.optimized and self.pl.share_masks:
            # New serve epoch: masks derived by earlier runs on this
            # planner's cache now count as cross-query hits.
            self.pl.mask_cache.begin_run()
        return self._run(cq, validate, warm=False)

    def run_compiled(self, cq: CompiledQuery, validate: bool = True) -> dict:
        """Workload path: atoms were requested and flushed batch-wide by
        `run_workload`; execute against the warm shared evaluator."""
        self._static_verify(cq, mirror_begin_run=False, warm=True)
        return self._run(cq, validate, warm=True)

    def _static_verify(self, cq: CompiledQuery, mirror_begin_run: bool,
                       warm: bool) -> None:
        """Static admission (DESIGN §10): abstract-interpret the compiled
        DAG against the noise/level/placement model before any ciphertext
        work; error-severity findings reject the plan here.  Opt out with
        Planner(..., verify=False)."""
        self._verify_report = None
        if not getattr(self.pl, "verify_plans", True):
            return
        from .verify import verify_compiled
        rep = verify_compiled(self.pl, cq, mirror_begin_run=mirror_begin_run,
                              warm=warm)
        self._verify_report = rep
        rep.raise_on_error()

    def _run(self, cq: CompiledQuery, validate: bool, warm: bool) -> dict:
        pl, bk = self.pl, self.bk
        pr = pl.report(cq.plan)
        self.report = ExecReport(cq.plan.name, pl.optimized,
                                 pr.predicted_depth, pr.predicted_refreshes,
                                 pr.budget_levels)
        cache = pl.mask_cache
        cs0 = cache.stats.clone()
        start = bk.stats.clone()
        prior_max = bk.stats.max_depth
        bk.stats.max_depth = 0
        # Guards are armed by an injected FaultPlan or Planner(guards=
        # True).  The sentinel lane only makes sense where the plan
        # promises refresh-free depth (optimized): it replays the run's
        # observed depth on a known plaintext with auto-refresh off.
        self._guards = faults.active() is not None or getattr(pl, "guards", False)
        self._sentinel = (faults.SentinelLane(bk)
                          if self._guards and pl.optimized
                          and pr.predicted_refreshes == 0 else None)
        det = getattr(pl, "straggler_det", None)
        costs = getattr(pl, "op_costs", None) or {}
        ctx0 = getattr(pl, "shard_ctx", None)
        led0 = ctx0.modeled_seconds(costs) if (det and ctx0) else 0.0
        ckpt = StageCheckpoint()
        overflow_tries = 0
        loss_tries = 0
        from .sharded import activate
        try:
            while True:
                try:
                    # Sharded scan execution: with a planner shard
                    # context every stacked column launched below
                    # pads/places its block lanes over the mesh data
                    # axis (no-op when shard_ctx is None).  Re-read per
                    # attempt: device-loss recovery swaps the context.
                    with activate(bk, getattr(pl, "shard_ctx", None)):
                        with faults.tampered_noise_model(bk):
                            out = self._execute(cq, warm, ckpt=ckpt)
                    break
                except faults.DeviceLossFault as f:
                    self._recover_device_loss(f, ckpt, loss_tries)
                    loss_tries += 1
                except faults.NoiseOverflowFault as f:
                    self._recover_overflow(f, ckpt, overflow_tries)
                    overflow_tries += 1
            if det is not None and getattr(pl, "shard_ctx", None) is not None:
                self._straggler_round(det, costs, ctx0, led0)
        finally:
            end = bk.stats.clone()
            self.report.measured_depth = bk.stats.max_depth
            self.report.refreshes = end.refresh - start.refresh
            self.report.launches = end.launches - start.launches
            self.report.muls = end.mul - start.mul
            self.report.cache_hits = cache.stats.hits - cs0.hits
            self.report.cache_admit_refreshes = (
                cache.stats.admit_refresh_blocks - cs0.admit_refresh_blocks)
            bk.stats.max_depth = max(prior_max, bk.stats.max_depth)
            self._sentinel = None
        if validate:
            self.report.validate()
            if (self._verify_report is not None and not self.report.recoveries
                    and faults.active() is None):
                # Soundness: the static bound at every decrypt boundary
                # must be no tighter than what execution observed.
                self._verify_report.crosscheck(self.report)
        return out

    # --------------------------------------------------------- recovery
    def _recover_device_loss(self, f, ckpt: StageCheckpoint,
                             tries: int) -> None:
        """Reshard onto the survivors and resume from the checkpoint.
        Raises the fault through when no viable mesh remains or the
        retry budget is spent."""
        pl = self.pl
        ctx = getattr(pl, "shard_ctx", None)
        if ctx is None or tries >= MAX_DEVICE_LOSS_RECOVERIES:
            raise f
        try:
            new_ctx = ctx.reshard([f.worker if f.worker is not None else 0])
        except RuntimeError as e:
            raise faults.DeviceLossFault(
                f"{self.report.name}: no viable scan mesh after losing "
                f"worker {f.worker}: {e}", query=self.report.name,
                stage=f.stage, worker=f.worker) from e
        pl.shard_ctx = new_ctx
        ckpt.resumes += 1
        self.report.recoveries.append({
            "kind": f.kind, "stage": f.stage, "worker": f.worker,
            "action": f"reshard {ctx.shards}->{new_ctx.shards}, resume "
                      f"after {ckpt.completed()}"})

    def _recover_overflow(self, f, ckpt: StageCheckpoint,
                          tries: int) -> None:
        """Bounded overflow recovery: refresh-and-retry, then re-derive
        from base columns, then typed failure (DESIGN §9)."""
        pl, bk = self.pl, self.bk
        if tries >= MAX_OVERFLOW_RETRIES:
            raise f
        if tries == 0:
            # The tracked noise of every materialized mask is suspect —
            # rejuvenate the checkpointed blocks, drop cache entries
            # (their born_levels were priced with the bad model), retry.
            ckpt.refresh_all(bk)
            pl.mask_cache.clear()
            action = "refresh-and-retry"
        else:
            # Refreshing did not clear the overflow: the materialized
            # values themselves are suspect.  Re-derive everything from
            # base columns.
            ckpt.clear()
            pl.mask_cache.clear()
            action = "re-derive-from-base"
        if self._sentinel is not None:
            self._sentinel = faults.SentinelLane(bk)
        self.report.recoveries.append({
            "kind": f.kind, "stage": f.stage, "action": action,
            "detail": f.detail})

    def _straggler_round(self, det, costs: dict, ctx0, led0: float) -> None:
        """Elastic loop: per-worker heartbeats from this run's cost-
        ledger delta, detector evaluation, and reshard away exclusions.
        Workers enumerate the flattened 2-D grid (id = data_row *
        limb_shards + limb_col); either mesh axis shrinks independently:
        a limb *column* whose every data row is flagged is a model-axis
        exclusion (elastic_limb_plan), anything else shrinks the data
        axis by the flagged rows (elastic_scan_plan) — at limb_shards=1
        this reduces exactly to the 1-D policy.  A fleet with no viable
        survivor mesh raises a typed fault."""
        pl = self.pl
        ctx = pl.shard_ctx
        plan = faults.active()
        slow = plan.straggler_slowdown if plan is not None else {}
        base = led0 if ctx is ctx0 else 0.0
        for worker, t in ctx.heartbeats(costs, slow, baseline=base).items():
            det.report(worker, t)
        excluded = [w for w in det.evaluate() if w < ctx.workers]
        if not excluded:
            return
        M = ctx.limb_shards
        flagged = set(excluded)
        limb_cols = [m for m in range(M)
                     if all(d * M + m in flagged for d in range(ctx.shards))]
        if M > 1 and limb_cols and len(limb_cols) < M:
            axis, drop = "model", limb_cols
        else:
            axis, drop = "data", sorted({w // M for w in excluded})
        try:
            new_ctx = ctx.reshard(drop, axis=axis)
        except RuntimeError as e:
            raise faults.StragglerFault(
                f"{self.report.name}: straggler exclusion {excluded} "
                f"leaves no viable scan mesh: {e}",
                query=self.report.name, stage="straggler",
                detail={"excluded": excluded, "axis": axis}) from e
        pl.shard_ctx = new_ctx
        self.report.recoveries.append({
            "kind": "straggler", "excluded": excluded, "axis": axis,
            "action": (f"reshard {axis} "
                       f"{ctx.shards}x{ctx.limb_shards}->"
                       f"{new_ctx.shards}x{new_ctx.limb_shards}")})

    # ------------------------------------------------------- compilation
    def _split_group_in(self, where, group_cols):
        """Group pushdown: an IN predicate on the (single) group column
        defines the group domain and leaves the WHERE tree — the group
        enumeration already restricts to exactly those values."""
        group_values: dict[str, list] = {}
        if len(group_cols) != 1 or where is None:
            return where, group_values
        col = group_cols[0]
        is_group_in = lambda e: isinstance(e, Pred) and e.col == col and e.op == "in"
        if is_group_in(where):
            return None, {col: list(where.value)}
        if isinstance(where, And):
            hit = [c for c in where.children if is_group_in(c)]
            if hit:
                # Absorb exactly one IN into the group enumeration; any
                # further predicates on the group column stay in WHERE.
                kept = [c for c in where.children if c is not hit[0]]
                group_values[col] = list(hit[0].value)
                if not kept:
                    where = None
                elif len(kept) == 1:
                    where = kept[0]
                else:
                    where = And(tuple(kept))
        return where, group_values

    def _group_items(self, fact, group_cols, group_values):
        """Per group column: [(name, encoded id), ...] in output order.
        Pushed-down values encode with predicate semantics (constants
        absent from the data map to a no-match id -> empty group)."""
        per_col = []
        for col in group_cols:
            spec = fact.schema.col(col)
            if col in group_values:
                per_col.append([(v, spec.encode_scalar(v))
                                for v in group_values[col]])
            elif spec.dictionary is not None:
                per_col.append(sorted(spec.dictionary.items()))
            else:
                raise NotImplementedError(
                    f"group_by {col}: no dictionary and no IN predicate to "
                    f"enumerate the domain from")
        return per_col

    # ------------------------------------------------------- compilation
    def compile(self, plan: QueryPlan) -> CompiledQuery:
        """Lower one plan to annotated mask trees (no ciphertext work)."""
        if plan.correlated:
            raise NotImplementedError(
                f"{plan.name}: correlated subqueries are not lowered yet")
        db = self.db
        fact = db.tables[plan.fact]
        group_cols = ([c.strip() for c in plan.group_by.split(",")]
                      if plan.group_by else [])
        where_expr, group_values = self._split_group_in(plan.where, group_cols)
        per_col_items = self._group_items(fact, group_cols, group_values)
        where_node = (compile_mask(db, fact, where_expr)
                      if where_expr is not None else None)
        aux_nodes = {a.name: (a, compile_mask(db, db.tables[a.hop.parent], a.expr))
                     for a in plan.aux_masks}
        inject_layers = (2 if group_cols else 1) \
            + max((a.mul_depth() for a in plan.aggs), default=0)
        if where_node is not None:
            annotate_downstream(where_node, inject_layers)
        for _, node in aux_nodes.values():
            annotate_downstream(node, 2)   # AND with base + R3 injection
        return CompiledQuery(plan, fact, group_cols, where_expr, group_values,
                             per_col_items, where_node, aux_nodes,
                             inject_layers)

    def request_atoms(self, cq: CompiledQuery, ev) -> None:
        """Register every distinct comparison circuit of the query (WHERE
        + aux + group EQs) with the shared evaluator, each carrying its
        downstream-product requirement for noise-aware cache admission."""
        if cq.where_node is not None:
            ev.request_tree(cq.where_node)
        for _, node in cq.aux_nodes.values():
            ev.request_tree(node)
        for col, items in zip(cq.group_cols, cq.per_col_items):
            for _name, vid in items:
                ev.request(CmpAtom(cq.fact.name, col, "eq", int(vid)),
                           cq.inject_layers)

    # --------------------------------------------------------- execution
    @staticmethod
    def _gmask_blocks(gmasks: dict) -> list:
        return [b for d in gmasks.values() for blocks in d.values()
                for b in blocks]

    def _execute(self, cq: CompiledQuery, warm: bool = False,
                 ckpt: StageCheckpoint | None = None) -> dict:
        pl, bk = self.pl, self.bk
        plan, fact = cq.plan, cq.fact
        stats = bk.stats
        group_cols, per_col_items = cq.group_cols, cq.per_col_items
        where_expr, where_node, aux_nodes = (cq.where_expr, cq.where_node,
                                             cq.aux_nodes)
        # Stage boundaries double as checkpoints: a completed stage's
        # payload is replayed on resume instead of re-derived, and as
        # injection points for the device-loss fault class.
        ckpt = ckpt if ckpt is not None else StageCheckpoint()

        if pl.optimized:
            # Stage 1 — fused atom evaluation: every distinct comparison
            # circuit in the query is requested up front and evaluated in
            # one stacked launch per shape.  Warm (workload) executions
            # arrive with the batch-wide flush already done.
            ev = self.ev if self.ev is not None else pl.evaluator()
            if not ckpt.has("atoms"):
                faults.maybe_device_loss("atoms")
                snap = stats.clone()
                if not warm:
                    self.request_atoms(cq, ev)
                    ev.flush()
                self.report.record("atoms[fused]", snap, stats.clone())
                ckpt.put("atoms", True)

            if ckpt.has("where"):
                where = ckpt.get("where")
            else:
                faults.maybe_device_loss("where")
                snap = stats.clone()
                where = (run_mask_node(where_node, ev, pl)
                         if where_node is not None else None)
                self.report.record("where", snap, stats.clone())
                ckpt.put("where", where, blocks=where or ())

            aux = {}
            for name, (a, node) in aux_nodes.items():
                stage = f"aux:{name}"
                if ckpt.has(stage):
                    aux[name] = ckpt.get(stage)
                    continue
                faults.maybe_device_loss(stage)
                snap = stats.clone()
                aux[name] = self._translate_aux(a, node, ev, None)
                self.report.record(stage, snap, stats.clone())
                ckpt.put(stage, aux[name], blocks=aux[name])

            if ckpt.has("gmasks"):
                gmasks = ckpt.get("gmasks")
            elif group_cols:
                faults.maybe_device_loss("gmasks")
                gmasks = {
                    col: dict(ev.eq_masks(fact, col,
                                          [vid for _n, vid in items],
                                          need_levels=cq.inject_layers))
                    for col, items in zip(group_cols, per_col_items)
                }
                ckpt.put("gmasks", gmasks,
                         blocks=self._gmask_blocks(gmasks))
            else:
                gmasks = {}
        else:
            # Classical pipeline: sequential chains, no fusion, joins over
            # filtered FK columns, raw group EQs combined after the WHERE.
            if ckpt.has("where"):
                where = ckpt.get("where")
            else:
                faults.maybe_device_loss("where")
                snap = stats.clone()
                where = (pl.where_mask(fact, where_expr)
                         if where_expr is not None else None)
                self.report.record("where[seq]", snap, stats.clone())
                ckpt.put("where", where, blocks=where or ())
            aux = {}
            for name, (a, node) in aux_nodes.items():
                stage = f"aux:{name}"
                if ckpt.has(stage):
                    aux[name] = ckpt.get(stage)
                    continue
                faults.maybe_device_loss(stage)
                snap = stats.clone()
                fk_ov = (ops.mask_columns(bk, fact.col(a.hop.fk).blocks, where)
                         if where is not None else None)
                aux[name] = self._translate_aux(a, node, None, fk_ov)
                self.report.record(f"{stage}[pushdown]", snap, stats.clone())
                ckpt.put(stage, aux[name], blocks=aux[name])
            if ckpt.has("gmasks"):
                gmasks = ckpt.get("gmasks")
            elif group_cols:
                faults.maybe_device_loss("gmasks")
                gmasks = {
                    col: dict(ops.group_masks(bk, fact, col,
                                              [vid for _n, vid in items]))
                    for col, items in zip(group_cols, per_col_items)
                }
                ckpt.put("gmasks", gmasks,
                         blocks=self._gmask_blocks(gmasks))
            else:
                gmasks = {}

        # The aggregate is never checkpointed — its outputs are the
        # decrypted results themselves, which must re-derive under any
        # recovery so the guards re-check them.
        faults.maybe_device_loss("aggregate")
        snap = stats.clone()
        out = (self._grouped(plan, fact, per_col_items, gmasks, where, aux)
               if group_cols else self._ungrouped(plan, fact, where))
        self.report.record("aggregate", snap, stats.clone())
        return out

    def _translate_aux(self, a, node, ev, fk_override):
        """Aux mask: parent-table subtree -> translated fact mask."""
        pl, bk, db = self.pl, self.bk, self.db
        if ev is not None:
            parent_mask = run_mask_node(node, ev, pl)
        else:
            parent_mask = pl.where_mask(db.tables[a.hop.parent], a.expr)
        assert len(parent_mask) == 1, "aux translate: single-block parent"
        need = pl.translate_levels(node.downstream_muls)
        return ops.translate_mask_down(bk, parent_mask[0], db.tables[a.hop.child],
                                       a.hop.fk, db.tables[a.hop.parent].nrows,
                                       fk_override=fk_override, need_levels=need,
                                       eq_cache=None if ev is None else ev.cache)

    # ------------------------------------------------------- aggregation
    def _dec(self, ct):
        """The decrypt boundary.  With guards armed every result passes
        the headroom check (tracked budget minus any model-hidden growth
        must clear zero) and the sentinel lane replays the run's
        observed depth on a known plaintext — both raise a typed
        NoiseOverflowFault *before* a garbage value can be returned."""
        if self._guards:
            faults.check_decrypt(self.bk, ct,
                                 query=self.report.name if self.report else "")
            if self._sentinel is not None:
                self._sentinel.verify(
                    self.bk.stats.max_depth,
                    query=self.report.name if self.report else "")
        if self.report is not None:
            self.report.decrypt_headrooms.append(float(self.bk.budget(ct)))
        return int(self.bk.decrypt(ct)[0])

    def _dec_agg(self, agg, r):
        if agg.kind == "avg":
            return (self._dec(r[0]), self._dec(r[1]))
        return self._dec(r)

    def _ungrouped(self, plan, fact, where) -> dict:
        pl = self.pl
        return {agg.name: self._dec_agg(agg, pl.aggregate(fact, agg, where))
                for agg in plan.aggs}

    def _grouped(self, plan, fact, per_col_items, gmasks, where, aux) -> dict:
        pl, bk = self.pl, self.bk
        out = {}
        for combo in itertools.product(*per_col_items):
            key = combo[0][0] if len(combo) == 1 else tuple(n for n, _ in combo)
            gm_lists = [gmasks[col][vid]
                        for col, (_n, vid) in zip(gmasks, combo)]
            legs = gm_lists + ([where] if where is not None else [])
            if pl.optimized:
                base = ops.and_masks(bk, legs) if len(legs) > 1 else legs[0]
            else:
                seq = ([where] + gm_lists) if where is not None else gm_lists
                base = ops.and_masks_seq(bk, seq) if len(seq) > 1 else seq[0]
            base = ops.apply_validity(bk, base, fact)
            row, parts = {}, {}
            for agg in plan.aggs:
                if agg.partition is None:
                    row[agg.name] = self._dec_agg(
                        agg, pl._agg_with_mask(fact, agg, base))
                    continue
                if agg.partition not in parts:
                    am = aux[agg.partition]
                    parts[agg.partition] = (
                        ops.and_masks(bk, [base, am]) if pl.optimized
                        else ops.and_masks_seq(bk, [base, am]))
                hit = parts[agg.partition]
                m = ([bk.sub(b, h) for b, h in zip(base, hit)]
                     if agg.negated else hit)      # complement = base - hit
                row[agg.name] = self._dec_agg(
                    agg, pl._agg_with_mask(fact, agg, m))
            out[key] = row
        return out


def run_via_plan(planner, plan: QueryPlan, validate: bool = True,
                 shards: int | None = None,
                 limb_shards: int | None = None,
                 verify: bool | None = None) -> dict:
    """Execute a QueryPlan through the compiled operator DAG.  Returns
    the same decrypted result structure as the legacy `run_qN` body.

    `shards=N` runs this plan's scan phase sharded over N mesh data
    lanes and `limb_shards=M` shards the k RNS limbs over M model-axis
    lanes (engine/sharded.py) without mutating the planner's default:
    the context is installed for this call only.  `verify` overrides the
    planner's static-verification knob for this call only (None keeps
    the planner default)."""
    prev_verify = getattr(planner, "verify_plans", True)
    if verify is not None:
        planner.verify_plans = verify
    try:
        if shards is None and limb_shards is None:
            # No context installed: leave planner.shard_ctx alone so a
            # mid-run recovery's resharding stays observable post-call.
            return Executor(planner).run(plan, validate=validate)
        from .sharded import make_shard_context
        prev = getattr(planner, "shard_ctx", None)
        planner.shard_ctx = make_shard_context(
            shards if shards is not None else 1,
            limb_shards=limb_shards if limb_shards is not None else 1,
            limbs=getattr(planner.bk, "limbs", None),
            ring_n=getattr(planner.bk, "slots", 0))
        try:
            return Executor(planner).run(plan, validate=validate)
        finally:
            planner.shard_ctx = prev
    finally:
        planner.verify_plans = prev_verify
