"""Baseline cost models and the NSHEDB timing model (paper §5).

Three time sources feed the comparison tables:

1. **NSHEDB (ours)** — executable.  Small parameter sets run genuinely on
   the BFV backend; paper-scale runs execute on the mock backend and are
   priced as  sum(op_count x per-op seconds) + refreshes x C_boot,  with
   per-op seconds *measured* on our JAX BFV implementation and
   extrapolated to paper parameters with the analytic complexity model
   below (cost ~ a*k*n*log n NTT work + b*k^2*n base-conversion work).

2. **HE3DB / ArcEDB** — the paper's baselines, not reimplementable in
   scope (each is a CCS-paper-sized system).  We price them from the
   paper's own primitive-operation measurements (Table 4, per-slot ms on
   the same 32K-row setting), applied to the operator counts our engine
   logs: time = sum_ops count x cost_per_slot x rows.  Where the paper
   quotes whole-query times (Q1/Q6/Q8 in §5.2.2, Table 5) we report
   those verbatim as "paper-reported" anchors.

3. **Bootstrap constant** — C_boot = 44 s per ciphertext refresh, the
   CKKS figure the paper cites from [3] (44 s / 32,768 elements); used to
   price our (rare, planned) refreshes and the unoptimized plans.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

# --------------------------------------------------------------------------
# Paper constants.
# --------------------------------------------------------------------------

# Table 4: per-slot milliseconds at 32K rows.
TABLE4_MS_PER_SLOT = {
    "he3db":  {"count": 1.27, "sum": 1.27, "eq": 283.33, "cmp": 150.83,
               "between": 287.35, "in": 283.33, "groupby": 283.33},
    "arcedb": {"count": 1.27, "sum": 1.27, "eq": 16.00, "cmp": 16.00,
               "between": 33.69, "in": 16.00, "groupby": 16.00},
    "nshedb_paper": {"count": 0.04, "sum": 0.04, "eq": 0.09, "cmp": 3.66,
                     "between": 7.32, "in": 0.09, "groupby": 0.09},
}

# §5.2.2 / Table 5: whole-query seconds quoted in the text (32K rows).
PAPER_QUERY_SECONDS = {
    "Q1": {"he3db": 14454.0, "arcedb": 4748.0, "nshedb_noopt": 477.0},
    "Q6": {"he3db": 11802.0, "arcedb": 3257.0, "nshedb": 590.0},
    "Q8": {"he3db": 8423.0, "arcedb": 3351.0, "nshedb": 178.0},
}

C_BOOT_SECONDS = 44.0          # CKKS bootstrap of one 32K ciphertext [3]
PAPER_SLOTS = 32768


def baseline_seconds(system: str, op_log: dict, rows: int) -> float:
    """Bit-level baseline estimate: operator counts x Table-4 per-slot
    cost x live rows (bit-level systems pay per row, not per block)."""
    tab = TABLE4_MS_PER_SLOT[system]
    sec = 0.0
    for op, cnt in op_log.items():
        if op in tab:
            sec += cnt * tab[op] * rows / 1000.0
    return sec


# --------------------------------------------------------------------------
# NSHEDB per-op cost calibration (measured on our JAX BFV, extrapolated).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpCosts:
    """Per-op seconds for one parameter set (n, k)."""

    n: int
    k: int
    mul: float
    mul_plain: float
    mul_scalar: float
    add: float
    rotate: float
    refresh: float = C_BOOT_SECONDS

    def as_dict(self) -> dict[str, float]:
        return {"mul": self.mul, "mul_plain": self.mul_plain,
                "mul_scalar": self.mul_scalar, "add": self.add,
                "rotate": self.rotate, "refresh": self.refresh}


def measure_costs(params, reps: int = 3, seed: int = 0) -> OpCosts:
    """Wall-clock per-op costs of the real BFV backend at `params`."""
    from .backend import BFVBackend

    bk = BFVBackend(params, seed=seed)
    a = bk.encrypt(np.arange(params.n) % params.t)
    b = bk.encrypt(np.arange(params.n)[::-1] % params.t)
    mask = (np.arange(params.n) % 2).astype(np.int64)

    def timeit(fn):
        fn()                                 # warm-up (jit compile)
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn()
            if hasattr(r, "data"):
                r.data.block_until_ready()
        return (time.perf_counter() - t0) / reps

    return OpCosts(
        n=params.n, k=params.k,
        mul=timeit(lambda: bk.mul(a, b)),
        mul_plain=timeit(lambda: bk.mul_plain(a, mask)),
        mul_scalar=timeit(lambda: bk.mul_scalar(a, 3)),
        add=timeit(lambda: bk.add(a, b)),
        rotate=timeit(lambda: bk.rotate(a, 1)),
    )


def extrapolate_costs(measured: OpCosts, n2: int, k2: int) -> OpCosts:
    """Scale measured costs to another (n, k).

    Complexity model per op (RNS-BFV):
      mul        ~ k*n*log n (NTTs)  +  k^2*n (HPS base conversions + KS)
      rotate     ~ k*n*log n          +  k^2*n (key-switch digits)
      mul_plain  ~ k*n*log n
      mul_scalar ~ k*n
      add        ~ k*n
    We conservatively attribute half the measured mul/rotate cost to each
    term at the measured point, then scale each term independently.
    """
    n1, k1 = measured.n, measured.k
    ntt = (k2 * n2 * np.log2(n2)) / (k1 * n1 * np.log2(n1))
    ks = (k2 * k2 * n2) / (k1 * k1 * n1)
    lin = (k2 * n2) / (k1 * n1)

    def two_term(c):
        return 0.5 * c * ntt + 0.5 * c * ks

    return OpCosts(
        n=n2, k=k2,
        mul=two_term(measured.mul),
        mul_plain=measured.mul_plain * ntt,
        mul_scalar=measured.mul_scalar * lin,
        add=measured.add * lin,
        rotate=two_term(measured.rotate),
    )


def nshedb_seconds(stats, costs: OpCosts) -> float:
    """Our engine's modeled wall-clock: op counts x per-op seconds."""
    c = costs.as_dict()
    return (stats.mul * c["mul"] + stats.mul_plain * c["mul_plain"]
            + stats.mul_scalar * c["mul_scalar"] + stats.add * c["add"]
            + stats.rotate * c["rotate"] + stats.refresh * c["refresh"])


def storage_report(profile_or_params, rows: int, ncols: int,
                   raw_bits: int = 16) -> dict:
    """Fig. 7(a): storage for `rows` x `ncols` 16-bit values.

    NSHEDB: ceil(rows/slots) ciphertexts per column.
    Bit-level baselines: ~8000x raw (the paper's §2.2 figure).
    """
    slots = profile_or_params.n
    nblocks = (rows + slots - 1) // slots
    nshedb = nblocks * ncols * profile_or_params.ct_bytes
    raw = rows * ncols * raw_bits // 8
    bitlevel = raw * 8000
    return {"raw_bytes": raw, "nshedb_bytes": nshedb,
            "bitlevel_bytes": bitlevel,
            "nshedb_expansion": nshedb / raw,
            "reduction_vs_bitlevel": bitlevel / nshedb}
