"""Static plan verification (DESIGN.md §10): prove a compiled QueryPlan
sound *before* any ciphertext is touched.

Three cooperating analyses over the physical IR of a `CompiledQuery`:

  noise abstract interpretation
      Re-executes the compiled DAG against an `AbstractBackend` whose
      values carry only (noise bound, depth, lane metadata) — the exact
      transfer functions of core/noise.py, the exact refresh policy of
      engine/backend.py, the exact cache-admission rule of
      engine/workload.py — but no payload.  Every decrypt boundary must
      end with positive invariant-noise headroom; every planned refresh
      is checked for sufficiency (exhaustion downstream of it is an
      error) and non-redundancy (a second, suppressed trajectory `nr`
      tracks what the noise *would* have been without the planned
      refresh — a refresh whose every observing decrypt clears the
      budget on the suppressed trajectory too is flagged dead).

  IR type / level checking
      Block shapes at lift time ((slots,) mock vectors, (2, k, n) RNS
      ciphertexts), and the scheduler's downstream-product annotations
      re-derived from the plan structure: a `downstream_muls` that does
      not match `annotate_downstream`'s recurrence means a planned
      refresh somewhere is sized from a tampered or stale level count —
      the statically visible form of "someone dropped a refresh".

  cache-aliasing + mesh-placement linting
      No in-place refresh may rejuvenate a cache entry that more than
      one consumer of this plan already holds (the PR 6 noise-unaware
      CSE bug class): entry blocks are tagged at insert/clone and every
      refresh event records how often its entry had been served.  Shard
      contexts are linted against the backend geometry (limb count,
      ring size, the k % M padding rule, data/model mesh axis extents)
      and the abstract run's collective counts are reconciled with the
      shadow ledger.

Verification is *pure*: it never touches the planner's backend, tables
or cache — everything is lifted into abstract shadows first.  The real
`OpStats` is untouched and no fault trigger is consumed (the abstract
backend deliberately never calls runtime/faults.py).

Entry points: `verify_plan(planner, plan)` / `verify_compiled(planner,
cq)`, `Planner.verify(plan)`, the executor's pre-run hook (opt out with
`Planner(..., verify=False)` or `run_via_plan(..., verify=False)`), and
`python -m repro.engine.verify` over every registered TPC-H builder.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .backend import _BackendBase
from .storage import EncryptedColumn, EncryptedTable
from .workload import CacheEntry, WorkloadCache


class PlanVerificationError(RuntimeError):
    """A compiled plan failed static verification (error-severity)."""


@dataclasses.dataclass
class Finding:
    severity: str        # 'error' | 'warning'
    code: str            # machine-readable rule id, e.g. 'noise.exhausted'
    where: str           # IR-node / stage provenance
    detail: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.code} @ {self.where}: {self.detail}"


@dataclasses.dataclass
class VerifyReport:
    """Structured result of one static verification pass."""

    name: str
    optimized: bool
    findings: list = dataclasses.field(default_factory=list)
    # Abstract decrypt boundaries, in execution order: each records the
    # static headroom (bits), the suppressed-refresh headroom, and the
    # planned-refresh sites whose effect reaches this decrypt.
    decrypts: list = dataclasses.field(default_factory=list)
    refresh_events: list = dataclasses.field(default_factory=list)
    predicted_depth: int = 0
    measured_depth: int = 0
    predicted_refreshes: int = 0
    budget_levels: int = 0
    skipped: bool = False      # plan not lowered (correlated / missing IR)

    @property
    def errors(self) -> list:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def add(self, severity: str, code: str, where: str, detail: str) -> None:
        self.findings.append(Finding(severity, code, where, detail))

    def raise_on_error(self) -> None:
        if self.errors:
            raise PlanVerificationError(
                f"{self.name}: static verification failed\n"
                + "\n".join(f"  {f}" for f in self.errors))

    def crosscheck(self, exec_report, eps: float = 1e-6) -> None:
        """Soundness obligation against a fault-free execution: the
        static headroom at every decrypt boundary must be no larger
        than the runtime-observed headroom (static noise bounds may
        only over-approximate), with identical boundary count/order."""
        obs = exec_report.decrypt_headrooms
        assert len(obs) == len(self.decrypts), (
            f"{self.name}: verifier saw {len(self.decrypts)} decrypt "
            f"boundaries, execution saw {len(obs)}")
        for i, (d, o) in enumerate(zip(self.decrypts, obs)):
            assert d["headroom"] <= o + eps, (
                f"{self.name}: decrypt #{i} static headroom "
                f"{d['headroom']:.3f} bits exceeds observed {o:.3f} — "
                f"the abstract model under-approximated noise")

    def summary(self) -> str:
        regime = "optimized" if self.optimized else "unoptimized"
        if self.skipped:
            why = "; ".join(f.code for f in self.findings) or "not lowered"
            return f"{self.name:<4} [{regime:<11}] SKIP ({why})"
        status = "ok" if self.ok else "FAIL"
        worst = min((d["headroom"] for d in self.decrypts), default=float("inf"))
        return (f"{self.name:<4} [{regime:<11}] {status}: depth "
                f"{self.measured_depth}/{self.predicted_depth} "
                f"(budget {self.budget_levels}), refreshes "
                f"{len([e for e in self.refresh_events if not e['admission']])}"
                f"/{self.predicted_refreshes} predicted, "
                f"{len(self.decrypts)} decrypts (min headroom "
                f"{worst:.1f} bits), {len(self.errors)} errors, "
                f"{len(self.warnings)} warnings")


# ---------------------------------------------------------------------------
# The abstract domain.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AbstractCipher:
    """A ciphertext with the payload erased: noise bound + lane metadata.

    `nr` is the counterfactual noise trajectory with planned refreshes
    suppressed (auto refreshes still apply — they would fire either
    way); comparing decrypt headroom on both trajectories is what
    separates a load-bearing planned refresh from a dead one.  `sites`
    carries the ids of the planned-refresh events whose effect reaches
    this value."""

    noise: "float | np.ndarray"
    nr: "float | np.ndarray"
    depth: int = 0
    nb: int = 1                  # logical (live) block lanes
    nphys: int = 1               # physical lanes incl. shard padding
    batch: bool = False
    sites: frozenset = frozenset()
    entry_key: "tuple | None" = None   # workload-cache entry this block IS


def _copy_noise(v):
    return float(v) if np.ndim(v) == 0 else np.asarray(v, dtype=np.float64).copy()


def _pack(noises: list) -> "float | np.ndarray":
    vals = [float(v) for v in noises]
    if all(v == vals[0] for v in vals):
        return vals[0]
    return np.asarray(vals, dtype=np.float64)


class AbstractBackend(_BackendBase):
    """MockBackend's noise/depth/charge semantics with no data.

    The whole operator surface (engine/ops.py, core/compare.py, the
    physical evaluator) runs unmodified against this class — every
    payload access in the engine lives inside backend methods, so the
    duck type holds.  Differences from the executing backends are
    deliberate and limited to: no payload math, no fault hooks (a
    verification pass must never consume a scheduled fault trigger),
    and event recording (refresh + decrypt boundaries)."""

    def __init__(self, bk):
        super().__init__()
        self.t = bk.t
        self.slots = bk.slots
        self.model = bk.model
        self.limbs = getattr(bk, "limbs", None)
        self.refresh_events: list = []
        self.decrypts: list = []
        self._stage = "compile"
        self._admission_key = None      # set by _VerifyCache.serve
        self._pending_refresh = None
        self._cache = None              # the _VerifyCache, for serve counts
        self._folds = 0
        self._gather_calls = 0

    # -- lane metadata ----------------------------------------------------
    def _nblocks(self, ct) -> int:
        return ct.nb if ct.batch else 1

    def _nblocks_phys(self, ct) -> int:
        return ct.nphys if ct.batch else 1

    def _meta(self, *cts):
        for c in cts:
            if c.batch:
                return c.nb, c.nphys, True
        return 1, 1, False

    @staticmethod
    def _sites(*cts) -> frozenset:
        out = frozenset()
        for c in cts:
            out |= c.sites
        return out

    def _mk(self, noise, nr, depth, *srcs) -> AbstractCipher:
        nb, nphys, batch = self._meta(*srcs)
        return AbstractCipher(noise, nr, depth, nb, nphys, batch,
                              self._sites(*srcs))

    def _entry_serves(self, key) -> int:
        if key is None or self._cache is None:
            return 0
        return self._cache.serve_log.get(key, 0)

    # -- refresh event recording ------------------------------------------
    def _charge_refresh(self, ct, lanes, what: str) -> None:
        super()._charge_refresh(ct, lanes, what)
        ev = {
            "id": len(self.refresh_events),
            "kind": "planned" if what.startswith("planned") else "auto",
            "what": what,
            "stage": self._stage,
            "lanes": list(lanes) if lanes is not None else None,
            "blocks": self._nblocks(ct) if lanes is None else len(lanes),
            "entry_key": ct.entry_key,
            # Inside a cache serve: the admission refresh the runtime's
            # validate() nets out of the plan-model invariants.
            "admission": self._admission_key is not None,
            "prior_serves": self._entry_serves(ct.entry_key),
        }
        self.refresh_events.append(ev)
        self._pending_refresh = ev

    def refresh_inplace(self, ct: AbstractCipher, lanes=None) -> None:
        ev, self._pending_refresh = self._pending_refresh, None
        planned = ev is not None and ev["kind"] == "planned"
        fresh = self.model.fresh()
        if lanes is not None and np.ndim(ct.noise):
            per = np.asarray(ct.noise, dtype=np.float64).copy()
            per[lanes] = fresh
            ct.noise = _pack(list(per))
            if planned:
                ct.sites = ct.sites | {ev["id"]}
            else:
                nr = (np.asarray(ct.nr, dtype=np.float64).copy()
                      if np.ndim(ct.nr)
                      else np.full(len(per), float(ct.nr)))
                nr[lanes] = fresh
                ct.nr = _pack(list(nr))
            return   # depth unchanged: un-refreshed lanes keep history
        ct.noise = fresh
        ct.depth = 0
        if planned:
            ct.sites = ct.sites | {ev["id"]}
        else:
            ct.nr = fresh

    def refresh(self, ct: AbstractCipher) -> AbstractCipher:
        fresh = self.model.fresh()
        return AbstractCipher(fresh, fresh, 0, ct.nb, ct.nphys, ct.batch,
                              ct.sites)

    def _charge_gather(self, *cts, mult: int = 1) -> None:
        ctx = self.shard_ctx
        if ctx is not None and getattr(ctx, "limb_shards", 1) > 1 and mult > 0:
            self._gather_calls += 1
        super()._charge_gather(*cts, mult=mult)

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> AbstractCipher:
        self.stats.encrypt += 1
        fresh = self.model.fresh()
        return AbstractCipher(fresh, fresh, 0)

    def decrypt(self, ct: AbstractCipher) -> np.ndarray:
        self.stats.decrypt += self._nblocks(ct)
        self.decrypts.append({
            "stage": self._stage,
            "headroom": float(np.min(self.model.budget(ct.noise))),
            "headroom_nr": float(np.min(self.model.budget(ct.nr))),
            "sites": set(ct.sites),
            "depth": ct.depth,
        })
        if ct.batch:
            return np.zeros((self._nblocks(ct), self.slots), dtype=np.int64)
        return np.zeros(self.slots, dtype=np.int64)

    def budget(self, ct: AbstractCipher) -> float:
        return self.model.min_budget(ct.noise)

    def depth(self, ct: AbstractCipher) -> int:
        return ct.depth

    # -- block batching ---------------------------------------------------
    def stack_blocks(self, blocks: list) -> AbstractCipher:
        assert all(not b.batch for b in blocks)
        nb = nphys = len(blocks)
        ctx = self.shard_ctx
        if ctx is not None and ctx.shards > 1 and nb > 1:
            from .sharded import pad_to
            nphys = pad_to(nb, ctx.shards)
        return AbstractCipher(_pack([b.noise for b in blocks]),
                              _pack([b.nr for b in blocks]),
                              max(b.depth for b in blocks), nb, nphys, True,
                              self._sites(*blocks))

    def unstack_blocks(self, batch: AbstractCipher) -> list:
        per_n = np.asarray(batch.noise) if np.ndim(batch.noise) else None
        per_r = np.asarray(batch.nr) if np.ndim(batch.nr) else None
        return [AbstractCipher(
                    float(per_n[i]) if per_n is not None else batch.noise,
                    float(per_r[i]) if per_r is not None else batch.nr,
                    batch.depth, sites=batch.sites)
                for i in range(self._nblocks(batch))]

    def fold_blocks(self, batch: AbstractCipher) -> AbstractCipher:
        # NB: the executing backends probe faults.maybe_device_loss here;
        # the abstract fold must not, or verification would consume the
        # chaos schedule meant for the real run.
        nb = self._nblocks(batch)
        self.stats.add += max(nb - 1, 0)
        self.stats.launches += 1
        if self.shard_ctx is not None:
            self.shard_ctx.record_fold(nb, self._nblocks_phys(batch))
        self._folds += 1
        per_n = batch.noise if np.ndim(batch.noise) else None
        per_r = batch.nr if np.ndim(batch.nr) else None
        noise = float(per_n[0]) if per_n is not None else batch.noise
        nr = float(per_r[0]) if per_r is not None else batch.nr
        for i in range(1, nb):
            noise = self.model.add(
                noise, float(per_n[i]) if per_n is not None else batch.noise)
            nr = self.model.add(
                nr, float(per_r[i]) if per_r is not None else batch.nr)
        return AbstractCipher(noise, nr, self._track_depth(batch.depth),
                              sites=batch.sites)

    # -- ring ops ----------------------------------------------------------
    def add(self, a, b):
        self._charge("add", a, b)
        return self._mk(self.model.add(a.noise, b.noise),
                        self.model.add(a.nr, b.nr),
                        self._track_depth(max(a.depth, b.depth)), a, b)

    def sub(self, a, b):
        self._charge("add", a, b)
        return self._mk(self.model.add(a.noise, b.noise),
                        self.model.add(a.nr, b.nr),
                        self._track_depth(max(a.depth, b.depth)), a, b)

    def neg(self, a):
        return self._mk(a.noise, a.nr, a.depth, a)

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if np.any(np.asarray(self._budget(post)) <= 0):
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(
                b, self.model.keyswitch(self.model.mul(a.noise, b.noise)),
                "mul")
        self._charge("mul", a, b)
        self._charge_gather(a, b)
        return self._mk(
            self.model.keyswitch(self.model.mul(a.noise, b.noise)),
            self.model.keyswitch(self.model.mul(a.nr, b.nr)),
            self._track_depth(max(a.depth, b.depth) + 1), a, b)

    def mul_plain(self, a, vec):
        a = self._maybe_refresh(a, self.model.mul_plain(a.noise), "mul_plain")
        self._charge("mul_plain", a)
        return self._mk(self.model.mul_plain(a.noise),
                        self.model.mul_plain(a.nr),
                        self._track_depth(a.depth + 1), a)

    def add_plain(self, a, vec):
        self._charge("add", a)
        return self._mk(self.model.add(a.noise, a.noise),
                        self.model.add(a.nr, a.nr), a.depth, a)

    def mul_scalar(self, a, c: int):
        self._charge("mul_scalar", a)
        return self._mk(self.model.mul_scalar(a.noise, c),
                        self.model.mul_scalar(a.nr, c), a.depth, a)

    def add_scalar(self, a, c: int):
        self._charge("add", a)
        return self._mk(self.model.add(a.noise, a.noise),
                        self.model.add(a.nr, a.nr), a.depth, a)

    def sub_from_scalar(self, c: int, a):
        self._charge("add", a)
        return self._mk(self.model.add(a.noise, a.noise),
                        self.model.add(a.nr, a.nr), a.depth, a)

    def dot_plain(self, cts: list, coeffs) -> AbstractCipher:
        cs = np.asarray(coeffs, dtype=np.int64) % self.t
        nz = [i for i in range(len(cts)) if cs[i] != 0]
        assert nz, "all-zero dot"
        used = [cts[i] for i in nz]
        nb = self._count(*used)
        phys = max(self._nblocks_phys(c) for c in used)
        dist = any(self._nblocks_phys(c) > 1 for c in used)
        self._charge_units("mul_scalar", len(nz) * nb, len(nz) * phys, dist)
        self._charge_units("add", max(0, len(nz) - 1) * nb,
                           max(0, len(nz) - 1) * phys, dist)
        noise = self.model.add_many(
            [self.model.mul_scalar(cts[i].noise, int(cs[i])) for i in nz])
        nr = self.model.add_many(
            [self.model.mul_scalar(cts[i].nr, int(cs[i])) for i in nz])
        depth = max(cts[i].depth for i in nz)
        return self._mk(noise, nr, self._track_depth(depth), *used)

    # -- data movement -----------------------------------------------------
    def rotate(self, a, step: int):
        hops = bin(step % (self.slots // 2)).count("1")
        self._charge("rotate", a, mult=hops)
        self._charge_gather(a, mult=hops)
        return self._mk(self.model.rotate(a.noise), self.model.rotate(a.nr),
                        a.depth, a)

    def swap_rows(self, a):
        self._charge("rotate", a)
        self._charge_gather(a)
        return self._mk(self.model.rotate(a.noise), self.model.rotate(a.nr),
                        a.depth, a)


# ---------------------------------------------------------------------------
# Shadow state: cache clone, lifted tables, shadow planner.
# ---------------------------------------------------------------------------

class _VerifyCache(WorkloadCache):
    """The workload cache over abstract entries, instrumented with
    per-entry serve counts (alias detection) and an admission scope on
    the backend so serve-time refreshes are distinguishable from
    translate-time planned refreshes.  Integrity is off: abstract
    handles carry no payload to fingerprint."""

    def __init__(self):
        super().__init__(policy="refresh", integrity="off")
        self.serve_log: dict = {}

    def serve(self, bk, atom, need_levels: int):
        bk._admission_key = atom.key
        try:
            out = super().serve(bk, atom, need_levels)
        finally:
            bk._admission_key = None
        if out is not None:
            self.serve_log[atom.key] = self.serve_log.get(atom.key, 0) + 1
        return out

    def insert(self, bk, atom, blocks: list) -> None:
        super().insert(bk, atom, blocks)
        for b in blocks:
            b.entry_key = atom.key


def _clone_cache(src: WorkloadCache, real_bk, abk) -> _VerifyCache:
    """Abstract shadow of the planner's cache: same keys, born levels
    and epoch, entries lifted to AbstractCipher at their *current* noise
    (an entry rejuvenated by an earlier run's refresh is served at that
    fresher level — exactly what the runtime would do)."""
    dst = _VerifyCache()
    dst.policy = src.policy
    dst.max_entries = src.max_entries
    dst._run = src._run
    for key, e in src.entries.items():
        blocks = [AbstractCipher(_copy_noise(b.noise), _copy_noise(b.noise),
                                 real_bk.depth(b), entry_key=key)
                  for b in e.blocks]
        dst.entries[key] = CacheEntry(blocks, e.table, e.born_levels,
                                      e.born_run, None)
    for key, e in src.fk_banks.items():
        bank = [[AbstractCipher(_copy_noise(b.noise), _copy_noise(b.noise),
                                real_bk.depth(b))
                 for b in masks] for masks in e.blocks]
        dst.fk_banks[key] = CacheEntry(bank, e.table, e.born_levels,
                                       e.born_run, None)
    return dst


class _ShimDB:
    """The minimal Database surface the planner/evaluator/executor touch."""

    def __init__(self, bk, tables: dict):
        self.bk = bk
        self.tables = tables

    def add_reload_hook(self, fn) -> None:
        pass     # shadow tables never reload


def _lift_block(b, real_bk, abk, rep: VerifyReport, where: str) -> AbstractCipher:
    """Lift one stored ciphertext handle, shape-checking it on the way."""
    vec = getattr(b, "vec", None)
    data = getattr(b, "data", None)
    if vec is not None:
        if vec.ndim != 1 or vec.shape[-1] != abk.slots:
            rep.add("error", "ir.shape", where,
                    f"stored mock block has shape {vec.shape}, "
                    f"expected ({abk.slots},)")
    elif data is not None:
        shape = tuple(np.shape(data))
        want = (2, abk.limbs, abk.slots)
        if abk.limbs is not None and shape != want:
            rep.add("error", "ir.shape", where,
                    f"stored ciphertext has shape {shape}, expected {want}")
    return AbstractCipher(_copy_noise(b.noise), _copy_noise(b.noise),
                          real_bk.depth(b))


def _lift_db(db, abk, rep: VerifyReport) -> _ShimDB:
    tables = {}
    for tname, t in db.tables.items():
        cols = {}
        for cname, c in t.columns.items():
            blocks = [_lift_block(b, db.bk, abk, rep, f"{tname}.{cname}[{i}]")
                      for i, b in enumerate(c.blocks)]
            cols[cname] = EncryptedColumn(c.name, c.spec, blocks, c.nrows)
        tables[tname] = EncryptedTable(t.name, t.schema, cols, t.nrows,
                                       t.slots)
    return _ShimDB(abk, tables)


def _shadow_planner(planner, adb, vcache):
    from .planner import Planner
    from .sharded import ShardContext
    spl = Planner(adb, optimized=planner.optimized, cache=vcache,
                  verify=False)
    spl.budget_levels = planner.budget_levels
    spl.fuse_masks = planner.fuse_masks
    spl.share_masks = planner.share_masks
    spl.guards = False
    ctx = getattr(planner, "shard_ctx", None)
    if ctx is not None:
        # Same geometry, fresh ledger, never a real mesh: verification
        # must not place anything on devices.
        spl.shard_ctx = ShardContext(ctx.shards, None,
                                     limb_shards=ctx.limb_shards,
                                     limbs=ctx.limbs, ring_n=ctx.ring_n)
    return spl


# ---------------------------------------------------------------------------
# The abstract driver: the executor's stage skeleton, minus fault hooks.
# ---------------------------------------------------------------------------

def _abstract_run(sx, cq, warm: bool) -> None:
    """Mirror of Executor._execute over the shadow state.  Kept separate
    from the real method because every real stage boundary probes
    faults.maybe_device_loss — a verification pass must not consume the
    chaos schedule armed for the actual execution."""
    from . import ops
    from .physical import run_mask_node

    pl, bk = sx.pl, sx.bk
    plan, fact = cq.plan, cq.fact
    group_cols, per_col_items = cq.group_cols, cq.per_col_items

    if pl.optimized:
        ev = sx.ev
        bk._stage = "atoms[fused]"
        if not warm:
            sx.request_atoms(cq, ev)
            ev.flush()
        bk._stage = "where"
        where = (run_mask_node(cq.where_node, ev, pl)
                 if cq.where_node is not None else None)
        aux = {}
        for name, (a, node) in cq.aux_nodes.items():
            bk._stage = f"aux:{name}"
            aux[name] = sx._translate_aux(a, node, ev, None)
        bk._stage = "gmasks"
        gmasks = {
            col: dict(ev.eq_masks(fact, col, [vid for _n, vid in items],
                                  need_levels=cq.inject_layers))
            for col, items in zip(group_cols, per_col_items)
        } if group_cols else {}
    else:
        bk._stage = "where"
        where = (pl.where_mask(fact, cq.where_expr)
                 if cq.where_expr is not None else None)
        aux = {}
        for name, (a, node) in cq.aux_nodes.items():
            bk._stage = f"aux:{name}"
            fk_ov = (ops.mask_columns(bk, fact.col(a.hop.fk).blocks, where)
                     if where is not None else None)
            aux[name] = sx._translate_aux(a, node, None, fk_ov)
        bk._stage = "gmasks"
        gmasks = {
            col: dict(ops.group_masks(bk, fact, col,
                                      [vid for _n, vid in items]))
            for col, items in zip(group_cols, per_col_items)
        } if group_cols else {}

    bk._stage = "aggregate"
    if group_cols:
        sx._grouped(plan, fact, per_col_items, gmasks, where, aux)
    else:
        sx._ungrouped(plan, fact, where)


# ---------------------------------------------------------------------------
# Rule analyses.
# ---------------------------------------------------------------------------

def _walk_annotations(node, expect: int, rep: VerifyReport, path: str) -> None:
    """Re-derive annotate_downstream's recurrence and flag any node whose
    recorded downstream_muls deviates: planned refreshes are sized from
    these counts, so a stale/tampered annotation is a mis-sized (or
    silently dropped) refresh."""
    if node.downstream_muls != expect:
        rep.add("error", "ir.levels", path,
                f"{node.kind} node on {node.table!r}: downstream_muls="
                f"{node.downstream_muls}, scheduler recurrence expects "
                f"{expect} — planned refreshes at/below this node are "
                f"sized from a stale level count")
    if node.kind in ("and", "or"):
        layers = math.ceil(math.log2(max(len(node.children), 2)))
        for i, c in enumerate(node.children):
            _walk_annotations(c, expect + layers, rep,
                              f"{path}.{node.kind}[{i}]")
    elif node.kind == "not":
        _walk_annotations(node.children[0], expect, rep, f"{path}.not")
    elif node.kind == "translated":
        _walk_annotations(node.children[0], expect + 2, rep,
                          f"{path}.translated({node.hop.fk})")


def _check_annotations(cq, rep: VerifyReport) -> None:
    expect_inject = ((2 if cq.group_cols else 1)
                     + max((a.mul_depth() for a in cq.plan.aggs), default=0))
    if cq.inject_layers != expect_inject:
        rep.add("error", "ir.levels", "inject",
                f"inject_layers={cq.inject_layers}, plan structure "
                f"requires {expect_inject}")
    if cq.where_node is not None:
        _walk_annotations(cq.where_node, cq.inject_layers, rep, "where")
    for name, (_a, node) in cq.aux_nodes.items():
        _walk_annotations(node, 2, rep, f"aux:{name}")


def _dead_refresh_ids(events: list, decrypts: list) -> list:
    """Planned (non-admission) refresh events whose every observing
    decrypt boundary clears the budget on the suppressed trajectory too
    — the refresh bought nothing.  Exposed pure for unit tests.

    Any auto refresh poisons the counterfactual: autos trigger off the
    *real* trajectory but reset both, so the suppressed trajectory may
    only stay positive because an auto rescued it — removing the
    planned refresh would then shift where the autos fire, and no
    single-trajectory argument proves it redundant.  Analysis is
    skipped (empty result) in that case."""
    if any(e["kind"] == "auto" for e in events):
        return []
    planned = {e["id"] for e in events
               if e["kind"] == "planned" and not e["admission"]}
    seen, needed = set(), set()
    for d in decrypts:
        for sid in d["sites"]:
            seen.add(sid)
            if d["headroom_nr"] <= 0:
                needed.add(sid)
    return sorted((planned & seen) - needed)


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------

def verify_compiled(planner, cq, mirror_begin_run: bool = True,
                    warm: bool = False) -> VerifyReport:
    """Statically verify one CompiledQuery against `planner`'s state.

    `mirror_begin_run` replays the serve-epoch bump `Executor.run` will
    perform right after verification; the warm workload path
    (`run_compiled`) passes False because its epoch already advanced.
    Pure: the planner's backend, tables and cache are never touched."""
    import dataclasses as _dc

    rep = VerifyReport(cq.plan.name, planner.optimized)
    pr = planner.report(cq.plan)
    rep.predicted_depth = pr.predicted_depth
    rep.predicted_refreshes = pr.predicted_refreshes
    rep.budget_levels = pr.budget_levels

    # --- IR typing: scheduler annotations (pure tree walk) ---------------
    _check_annotations(cq, rep)

    # --- mesh placement lint ---------------------------------------------
    ctx = getattr(planner, "shard_ctx", None)
    if ctx is not None:
        from .sharded import lint_shard_context
        for code, msg in lint_shard_context(
                ctx, limbs=getattr(planner.bk, "limbs", None),
                ring_n=getattr(planner.bk, "slots", 0)):
            rep.add("error", code, "shard_ctx", msg)

    # --- abstract interpretation -----------------------------------------
    from .executor import Executor

    abk = AbstractBackend(planner.bk)
    vcache = _clone_cache(planner.mask_cache, planner.bk, abk)
    abk._cache = vcache
    adb = _lift_db(planner.db, abk, rep)
    spl = _shadow_planner(planner, adb, vcache)
    if mirror_begin_run and planner.optimized and planner.share_masks:
        vcache.begin_run()
    acq = _dc.replace(cq, fact=adb.tables[cq.plan.fact])
    sx = Executor(spl, evaluator=spl.evaluator())
    from .sharded import activate
    try:
        with activate(abk, spl.shard_ctx):
            _abstract_run(sx, acq, warm)
    except Exception as e:    # noqa: BLE001 — any abstract failure is a finding
        rep.add("error", "verify.crash", abk._stage,
                f"abstract interpretation failed: {e!r}")
        return rep

    events, decrypts = abk.refresh_events, abk.decrypts
    rep.refresh_events = events
    rep.decrypts = decrypts
    rep.measured_depth = abk.stats.max_depth

    # --- noise: every decrypt boundary must clear the budget -------------
    for i, d in enumerate(decrypts):
        if d["headroom"] <= 0:
            rep.add("error", "noise.exhausted", d["stage"],
                    f"decrypt #{i}: static invariant-noise headroom "
                    f"{d['headroom']:.2f} bits <= 0 — the result would "
                    f"decrypt to garbage")

    # --- refreshes: the runtime validate() invariants, proven statically -
    non_admission = [e for e in events if not e["admission"]]
    if pr.predicted_refreshes == 0 and non_admission:
        code = "refresh.unplanned" if planner.optimized else "refresh.unpredicted"
        rep.add("error", code, non_admission[0]["stage"],
                f"plan predicts refresh-free execution but the abstract "
                f"run pays {len(non_admission)} refresh(es), first: "
                f"{non_admission[0]['what']}")

    for rid in _dead_refresh_ids(events, decrypts):
        e = events[rid]
        rep.add("warning", "refresh.dead", e["stage"],
                f"planned refresh '{e['what']}' is redundant: every "
                f"decrypt it reaches clears the budget without it")

    # --- cache aliasing (the PR 6 bug class) ------------------------------
    for e in events:
        if e["admission"] or e["entry_key"] is None:
            continue
        if e["prior_serves"] >= 2:
            sev = ("error" if planner.optimized
                   and pr.predicted_refreshes == 0 else "warning")
            rep.add(sev, "cache.alias", e["stage"],
                    f"in-place {e['kind']} refresh '{e['what']}' "
                    f"rejuvenates cache entry {e['entry_key']} already "
                    f"served to {e['prior_serves']} consumers — their "
                    f"noise trajectories diverge from the model")

    # --- depth: the plan model's slack bounds ------------------------------
    from .executor import DEPTH_SLACK_OVER, DEPTH_SLACK_UNDER
    if rep.measured_depth > pr.predicted_depth + DEPTH_SLACK_OVER:
        rep.add("error", "depth.over", "plan",
                f"abstract depth {rep.measured_depth} exceeds predicted "
                f"{pr.predicted_depth} (+{DEPTH_SLACK_OVER})")
    if (planner.optimized and vcache.stats.hits == 0
            and pr.predicted_depth > rep.measured_depth + DEPTH_SLACK_UNDER):
        rep.add("error", "depth.under", "plan",
                f"prediction {pr.predicted_depth} overshoots abstract "
                f"depth {rep.measured_depth} (+{DEPTH_SLACK_UNDER})")

    # --- mesh ledger reconciliation ----------------------------------------
    sctx = spl.shard_ctx
    if sctx is not None:
        if sctx.folds != abk._folds:
            rep.add("error", "mesh.ledger", "shard_ctx",
                    f"ledger recorded {sctx.folds} folds, abstract run "
                    f"performed {abk._folds}")
        if sctx.gathers != abk._gather_calls:
            rep.add("error", "mesh.ledger", "shard_ctx",
                    f"ledger recorded {sctx.gathers} key-switch gathers, "
                    f"abstract run charged {abk._gather_calls}")
        if sctx.limb_shards == 1 and sctx.gather_bytes != 0.0:
            rep.add("error", "mesh.ledger", "shard_ctx",
                    f"1-D mesh charged {sctx.gather_bytes} gather bytes — "
                    f"model-axis collectives on a data-only mesh")
    return rep


def verify_plan(planner, plan) -> VerifyReport:
    """Compile + statically verify one QueryPlan.  Plans the physical
    compiler cannot lower yet are reported as skipped (warning), not as
    verification failures."""
    from .executor import Executor

    rep = VerifyReport(plan.name, planner.optimized)
    try:
        cq = Executor(planner).compile(plan)
    except NotImplementedError as e:
        code = "ir.correlated" if plan.correlated else "ir.unsupported"
        rep.add("warning", code, plan.name, str(e))
        rep.skipped = True
        return rep
    except KeyError as e:
        rep.add("warning", "ir.unsupported", plan.name,
                f"plan references IR the compiler cannot lower yet: {e}")
        rep.skipped = True
        return rep
    return verify_compiled(planner, cq)


# ---------------------------------------------------------------------------
# CLI: verify every registered TPC-H plan builder in both regimes.
# ---------------------------------------------------------------------------

def _main(argv=None) -> int:
    import argparse
    import time

    from . import queries, tpch
    from .backend import MockBackend
    from .planner import Planner

    p = argparse.ArgumentParser(
        description="Static verification of all registered TPC-H plans "
                    "(noise abstract interpretation + IR typing + mesh "
                    "lint), both depth regimes, no ciphertext work.")
    p.add_argument("--only", default=None, help="verify a single query")
    p.add_argument("--shards", type=int, default=None,
                   help="lint against an N-way data-sharded context")
    p.add_argument("--limb-shards", type=int, default=None,
                   help="lint against an M-way limb-sharded model axis")
    args = p.parse_args(argv)

    bk = MockBackend()
    db = tpch.load(bk, tpch.Scale.tiny())
    stats0 = bk.stats.clone()
    errors = 0
    for name in sorted(queries.QUERIES):
        if args.only and name != args.only:
            continue
        plan = queries.QUERIES[name][0]()
        for optimized in (True, False):
            pl = Planner(db, optimized=optimized, verify=False)
            if args.shards or args.limb_shards:
                from .sharded import make_shard_context
                pl.shard_ctx = make_shard_context(
                    args.shards or 1, limb_shards=args.limb_shards or 1,
                    limbs=bk.limbs, ring_n=bk.slots)
            t0 = time.perf_counter()
            rep = verify_plan(pl, plan)
            dt = time.perf_counter() - t0
            print(f"{rep.summary()}  [{dt * 1000:.0f} ms]")
            for f in rep.findings:
                if not rep.skipped:
                    print(f"    {f}")
            errors += len(rep.errors)
    moved = [f.name for f in dataclasses.fields(stats0)
             if getattr(bk.stats, f.name) != getattr(stats0, f.name)]
    if moved:
        print(f"FATAL: verification touched real ciphertexts: {moved}")
        return 2
    print(f"{'FAIL' if errors else 'ok'}: {errors} error finding(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(_main())
