"""Physical scan-first operators (paper §4.2).

Every operator maps over the ciphertext blocks of a column — there is no
positional access (Table 1).  All functions take the backend `bk` first
and work identically on BFVBackend and MockBackend.

Column-at-a-time execution: operators stack a column's block list into
one batched handle (`bk.stack_blocks`), run the circuit once — the
comparison circuits in core/compare.py are backend-polymorphic, so a
single pass evaluates every block through one jitted call per primitive
— and unstack at the boundary.  Blocks share an op history, so OpStats
and the planner's noise/depth model are identical to the per-block loop;
singleton columns skip the batch layer entirely.

Cross-mask batching extends this across *columns*: distinct comparison
circuits of one query fuse into a single stacked launch per circuit
shape (engine/physical.py), and the per-key join EQs of
translate/fk_masks fuse the same way (`_per_key_eq`).

Masks are lists of blocks of encrypted {0,1}; aggregates are single
ciphertexts with the result replicated in every slot (the paper's
fixed-size output leakage).
"""
from __future__ import annotations

import numpy as np

from ..core import compare as cmp
from .plan import Factor, Pred
from .storage import EncryptedColumn, EncryptedTable


# ---------------------------------------------------------------------------
# Block-batch plumbing.
# ---------------------------------------------------------------------------

def _stacked(bk, blocks: list):
    """Stack a block list for one batched call; singletons pass through."""
    if len(blocks) == 1:
        return blocks[0], False
    return bk.stack_blocks(blocks), True


def _unstacked(bk, out, batched: bool) -> list:
    return bk.unstack_blocks(out) if batched else [out]


def mul_lists(bk, xs: list, ys: list) -> list:
    """Blockwise ct x ct product of two aligned block lists."""
    x, batched = _stacked(bk, xs)
    y, _ = _stacked(bk, ys)
    return _unstacked(bk, bk.mul(x, y), batched)


# ---------------------------------------------------------------------------
# Predicate masks.
# ---------------------------------------------------------------------------

def _scalar_cmp(bk, ct, op: str, v) -> object:
    if op == "=":
        return cmp.eq_scalar(bk, ct, v)
    if op == "!=":
        return cmp.not_(bk, cmp.eq_scalar(bk, ct, v))
    if op == "<":
        return cmp.lt_scalar(bk, ct, v)
    if op == ">":
        return cmp.gt_scalar(bk, ct, v)
    if op == "<=":
        return cmp.le_scalar(bk, ct, v)
    if op == ">=":
        return cmp.ge_scalar(bk, ct, v)
    if op == "between":
        lo, hi = v
        return cmp.between_scalar(bk, ct, lo, hi)
    if op == "in":
        if not v:
            return bk.mul_scalar(ct, 0)    # empty set: all-zero mask
        return cmp.in_set(bk, ct, v)
    raise ValueError(op)


def _col_cmp(bk, ct_l, op: str, ct_r) -> object:
    z = bk.sub(ct_l, ct_r)
    if op == "=":
        return cmp.eq_zero(bk, z)
    if op == "!=":
        return cmp.not_(bk, cmp.eq_zero(bk, z))
    if op == "<":
        return cmp.lt_zero(bk, z)
    if op == ">":
        return cmp.lt_zero(bk, bk.neg(z))
    if op == "<=":
        return cmp.not_(bk, cmp.lt_zero(bk, bk.neg(z)))
    if op == ">=":
        return cmp.not_(bk, cmp.lt_zero(bk, z))
    raise ValueError(op)


def pred_mask(bk, table: EncryptedTable, pred: Pred, col_override=None) -> list:
    """Evaluate one predicate over every block of its column(s) — the
    whole column runs through one batched comparison circuit.

    col_override substitutes pre-masked blocks (the unoptimized pipeline
    evaluates comparisons on filtered columns — that is the point)."""
    col = table.col(pred.col)
    blocks = col_override if col_override is not None else col.blocks
    if pred.rhs_col is not None:
        rhs = table.col(pred.rhs_col).blocks
        lhs_b, batched = _stacked(bk, blocks)
        rhs_b, _ = _stacked(bk, rhs)
        return _unstacked(bk, _col_cmp(bk, lhs_b, pred.op, rhs_b), batched)
    spec = col.spec
    if pred.op == "between":
        v = (spec.encode_scalar(pred.value[0]), spec.encode_scalar(pred.value[1]))
    elif pred.op == "in":
        v = [spec.encode_scalar(x) for x in pred.value]
    else:
        v = spec.encode_scalar(pred.value)
    x, batched = _stacked(bk, blocks)
    return _unstacked(bk, _scalar_cmp(bk, x, pred.op, v), batched)


# ---------------------------------------------------------------------------
# Mask algebra (blockwise).
# ---------------------------------------------------------------------------

def and_masks(bk, masks: list[list]) -> list:
    """Balanced product tree per block (R2 / §4.3.1), all blocks batched."""
    if len(masks[0]) == 1:
        return [cmp.mul_tree(bk, [m[0] for m in masks])]
    stacked = [bk.stack_blocks(m) for m in masks]
    return bk.unstack_blocks(cmp.mul_tree(bk, stacked))


def _chain_lists(bk, lists: list[list], combine) -> list:
    """Sequential pairwise combine of block lists, stacking each column
    once up front (not per step) and unstacking once at the end."""
    if len(lists[0]) == 1:
        out = lists[0][0]
        for m in lists[1:]:
            out = combine(out, m[0])
        return [out]
    stacked = [bk.stack_blocks(m) for m in lists]
    out = stacked[0]
    for m in stacked[1:]:
        out = combine(out, m)
    return bk.unstack_blocks(out)


def and_masks_seq(bk, masks: list[list]) -> list:
    """Sequential chain — the unoptimized baseline."""
    return _chain_lists(bk, masks, bk.mul)


def or_masks_seq(bk, masks: list[list]) -> list:
    """Sequential OR chain — the unoptimized baseline."""
    return _chain_lists(bk, masks, lambda a, b: cmp.or_(bk, a, b))


def or_masks(bk, masks: list[list]) -> list:
    if len(masks[0]) == 1:
        stacked = [m[0] for m in masks]
    else:
        stacked = [bk.stack_blocks(m) for m in masks]
    layer = stacked
    while len(layer) > 1:
        nxt = [cmp.or_(bk, layer[i], layer[i + 1]) for i in range(0, len(layer) - 1, 2)]
        if len(layer) % 2:
            nxt.append(layer[-1])
        layer = nxt
    return _unstacked(bk, layer[0], len(masks[0]) > 1)


def not_mask(bk, mask: list) -> list:
    x, batched = _stacked(bk, mask)
    return _unstacked(bk, cmp.not_(bk, x), batched)


def apply_validity(bk, mask: list, table: EncryptedTable) -> list:
    """Zero out the padding slots of the last block (plaintext multiply —
    row counts are public metadata)."""
    out = list(mask)
    v = table.validity(table.nblocks - 1)
    if v is not None:
        out[-1] = bk.mul_plain(out[-1], v)
    return out


def mask_columns(bk, blocks: list, mask: list) -> list:
    """Filter a column: col x mask (the SELECT of Eq. 5)."""
    return mul_lists(bk, blocks, mask)


# ---------------------------------------------------------------------------
# Aggregation (paper §4.2.2).
# ---------------------------------------------------------------------------

def expr_blocks(bk, table: EncryptedTable, factors: tuple, masked: dict | None = None) -> list:
    """Product of affine column factors: prod_f (f.add + f.mult * col_f)."""
    assert factors
    per_factor = []
    batched = False
    for f in factors:
        src = (masked or {}).get(f.col) if masked else None
        blocks = src if src is not None else table.col(f.col).blocks
        x, batched = _stacked(bk, blocks)
        if f.mult != 1:
            x = bk.mul_scalar(x, f.mult)
        if f.add != 0:
            x = bk.add_scalar(x, f.add)
        per_factor.append(x)
    out = per_factor[0]
    for nxt in per_factor[1:]:
        out = bk.mul(out, nxt)
    return _unstacked(bk, out, batched)


def reduce_blocks(bk, blocks: list) -> object:
    """Sum across blocks then rotate-reduce within the ciphertext: the
    doubling pattern of §4.2.2 COUNT/SUM — result in every slot."""
    if len(blocks) == 1:
        acc = blocks[0]
    else:
        acc = bk.fold_blocks(bk.stack_blocks(blocks))
    return bk.sum_slots(acc)


# One level per ct-ct product the mask still has to absorb, plus one
# level of slack for the fold/sum_slots add-and-rotate tail, whose noise
# is real but below a full multiplicative level.  Without the slack,
# edge-of-budget plans (Q19 optimized at depth 24 on a 25-level budget)
# decrypt ~1.4 bits past the budget.
INJECT_ADMIT_SLACK = 1


def admit_inject(bk, mask: list, muls: int = 1) -> list:
    """Decrypt-headroom admission where a mask enters an aggregation
    tail: past here it absorbs `muls` ct-ct products plus the reduction
    slop, so a lane that cannot take muls+1 more levels pays its planned
    refresh now instead of decrypting past the budget.  A no-op whenever
    the plan fits — the static verifier (engine/verify.py) proves every
    decrypt boundary positive.

    The top-up is noise maintenance, not a new encryption epoch, so the
    handle keeps its multiplicative chain length: whether the admission
    fires depends on the launch layout (fused CSE, per-block derivation
    and the legacy bodies reach here with slightly different noise), and
    depth accounting must not."""
    out = []
    for b in mask:
        d0 = bk.depth(b)
        b = bk.ensure_levels(b, muls + INJECT_ADMIT_SLACK)
        if bk.depth(b) < d0:
            bk.set_depth(b, d0)
        out.append(b)
    return out


def masked_sum(bk, value_blocks: list, mask: list) -> object:
    bk.op_log["sum"] += 1
    mask = admit_inject(bk, mask)
    return reduce_blocks(bk, mask_columns(bk, value_blocks, mask))


def count(bk, mask: list) -> object:
    bk.op_log["count"] += 1
    mask = admit_inject(bk, mask, muls=0)
    return reduce_blocks(bk, mask)


def partial_sums(bk, value_blocks: list, mask: list, chunk: int) -> list:
    """Exact-sum variant (beyond-paper): stop the rotate-reduce early so
    each ciphertext carries n/chunk partial sums that the client combines
    exactly — avoids mod-t wraparound for big aggregates at *fewer*
    rotations than the full reduction."""
    mask = admit_inject(bk, mask)
    filtered = mask_columns(bk, value_blocks, mask)
    out, batched = _stacked(bk, filtered)
    step = 1
    while step < chunk:
        out = bk.add(out, bk.rotate(out, step))
        step *= 2
    return _unstacked(bk, out, batched)


# ---------------------------------------------------------------------------
# Join / group-by machinery (paper §4.2.2, Fig. 2).
# ---------------------------------------------------------------------------

def group_masks(bk, table: EncryptedTable, col: str, domain: list[int]) -> list[tuple[int, list]]:
    """One EQ mask per distinct value — GROUP BY (§4.2.2) and ORDER BY
    (§4.2.3, enumerate the dictionary in order)."""
    x, batched = _stacked(bk, table.col(col).blocks)
    return [(v, _unstacked(bk, cmp.eq_scalar(bk, x, int(v)), batched)) for v in domain]


def sort_column(bk, table: EncryptedTable, col: str, domain: list[int],
                descending: bool = False, mask_provider=None):
    """Homomorphic ORDER BY (§4.2.3): reconstruct the column as an
    encrypted *sorted sequence*, scanning the domain in order.

    For each value v (ascending): its encrypted count c_v places |c_v|
    copies of v at slots [P_{v-1}, P_v) where P is the running prefix sum
    — realized as plaintext-slot-index comparisons against the encrypted
    prefix:  slot i holds v  iff  P_{v-1} <= i < P_v.  Fixed |D| domain
    iterations regardless of data (the §3 leakage argument: value
    frequencies stay hidden inside the comparisons).

    Cost: |D| x (1 EQ + aggregation + 2 comparisons) — Table 2's
    O(|D| * n/S) scan behaviour.  Single-block columns only (the paper's
    32K-row setting).

    mask_provider, if given, maps a domain value to its EQ mask block
    list — the planner passes its memoized/fused per-value EQ cache so a
    sort after a GROUP BY on the same column re-evaluates nothing."""
    assert table.nblocks == 1, "sort_column: single-block reconstruction"
    S = bk.slots
    idx = np.arange(S, dtype=np.int64)        # plaintext slot indices 0..S-1
    order = sorted(domain, reverse=descending)
    prefix = None                             # encrypted running count
    out = None
    for v in order:
        if mask_provider is not None:
            mask = list(mask_provider(int(v)))
        else:
            mask = [cmp.eq_scalar(bk, ct, int(v)) for ct in table.col(col).blocks]
        mask = apply_validity(bk, mask, table)
        c_v = count(bk, mask)                 # count in every slot
        new_prefix = c_v if prefix is None else bk.add(prefix, c_v)
        # prefix sits ~eq_depth deep and each placement costs ~lt_depth
        # more: planned refresh (i* infeasible branch), once per value.
        new_prefix = bk.ensure_levels(new_prefix, _eqd(bk.t) + 4)
        # slot i gets v  iff  prefix_{v-1} <= i  AND  i < prefix_v
        # i < P  <=>  0 < P - i  <=>  GT(P - i, 0); P-i in centered range.
        lo_ok = (cmp.not_(bk, cmp.lt_zero(bk, bk.add_plain(bk.neg(prefix), idx)))
                 if prefix is not None else None)   # i >= P_{v-1}
        hi_ct = bk.add_plain(bk.neg(new_prefix), idx)       # i - P_v
        hi_ok = cmp.lt_zero(bk, hi_ct)                      # i < P_v
        pos = hi_ok if lo_ok is None else bk.mul(lo_ok, hi_ok)
        term = bk.mul_scalar(pos, int(v))
        out = term if out is None else bk.add(out, term)
        prefix = new_prefix
    return out


def _per_key_eq(bk, fact_blocks: list, nparent: int) -> list[list]:
    """EQ(fk, j+1) for every dense parent key — all nparent circuits run
    in ONE cross-mask batched launch (the per-key square chains share a
    shape, so the scheduler stacks them like any other fused atoms).
    op_log still charges one logical EQ per key; per-block OpStats and
    noise are identical to the per-key loop."""
    x, batched = _stacked(bk, fact_blocks)
    nb = len(fact_blocks)
    zs = []
    for j in range(nparent):
        z = bk.sub_scalar(x, j + 1)
        zs.extend(bk.unstack_blocks(z) if batched else [z])
    if len(zs) == 1:
        flat = [cmp.eq_zero(bk, zs[0])]
    else:
        flat = bk.unstack_blocks(cmp.eq_zero(bk, bk.stack_blocks(zs)))
        if hasattr(bk, "op_log"):
            bk.op_log["eq"] += nparent - 1
    return [flat[j * nb : (j + 1) * nb] for j in range(nparent)]


def fk_masks(bk, table: EncryptedTable, fk: str, nparent: int,
             eq_cache=None) -> list[list]:
    """EQ masks for every dense parent key 1..nparent (JOIN step 2).

    With an `eq_cache` (a WorkloadCache), the whole per-key bank is
    memoized on (child table, fk, nparent): repeated FK translations —
    several hops over one fk within a query, or the same join across a
    workload's queries — stop re-running nparent EQ circuits."""
    if eq_cache is not None:
        bank = eq_cache.fk_lookup(bk, table.name, fk, nparent)
        if bank is None:
            bank = _per_key_eq(bk, table.col(fk).blocks, nparent)
            eq_cache.fk_store(bk, table.name, fk, nparent, bank)
        return bank
    return _per_key_eq(bk, table.col(fk).blocks, nparent)


def pack_scalars(bk, scalar_cts: list) -> object:
    """Pack per-key scalar ciphertexts (value in every slot) into one
    ciphertext with value j at slot j: sum_j ct_j x basis_j."""
    S = bk.slots
    acc = None
    for j, ct in enumerate(scalar_cts):
        basis = np.zeros(S, dtype=np.int64)
        basis[j] = 1
        term = bk.mul_plain(ct, basis)
        acc = term if acc is None else bk.add(acc, term)
    return acc


from .plan import eq_depth as _eqd


def translate_mask_down(bk, parent_mask_block, fact_table: EncryptedTable,
                        fk: str, nparent: int, fk_override: list | None = None,
                        need_levels: int = 6, eq_cache=None) -> list:
    """Push a parent-row mask through an FK: child_mask[r] =
    parent_mask[key(r)].  Per parent key: Extract+Broadcast the mask bit,
    EQ the fk column, multiply, accumulate (Fig. 2 steps 1-3).
    Cost O(nparent * nblocks) ops — Table 2's JOIN row.

    The fk column is stacked once and every per-key EQ runs batched over
    all its blocks; the broadcast mask bit joins by broadcasting into the
    batch (single x batch products are supported by both backends).

    The parent mask is refreshed *once* here if it cannot absorb the hop
    (planned, not per-key: the i* model's pay-one-bootstrap branch).

    fk_override substitutes pre-masked fk blocks: the unoptimized pipeline
    joins over already-filtered columns (Fig. 3(a)'s deep chains).

    need_levels sizes the planned refresh: the compiled-DAG scheduler
    passes 2 (translate internals) + the IR-counted downstream mask
    products, clamped by the i* rule; the legacy default of 6 matches
    the hand-written query bodies.

    eq_cache memoizes the per-key EQ bank (see fk_masks); it is skipped
    under fk_override — pre-masked fk columns are data-dependent and
    must not be shared."""
    parent_mask_block = bk.ensure_levels(parent_mask_block, need_levels)
    if fk_override is not None:
        return _translate_down(bk, parent_mask_block, fk_override, nparent)
    fact_blocks = fact_table.col(fk).blocks
    per_key = (fk_masks(bk, fact_table, fk, nparent, eq_cache)
               if eq_cache is not None else None)
    return _translate_down(bk, parent_mask_block, fact_blocks, nparent, per_key)


def translate_values_down(bk, packed_values, fact_table: EncryptedTable,
                          fk: str, nparent: int) -> list:
    """Pull per-parent values (packed: value_j at slot j) down to child
    rows: child_val[r] = value[key(r)].  Used by correlated subqueries
    (Q17's per-part AVG)."""
    packed_values = bk.ensure_levels(packed_values, 6)
    return _translate_down(bk, packed_values, fact_table.col(fk).blocks, nparent)


def broadcast_slots(bk, packed, idxs) -> list:
    """Fused broadcast_slot: extract+replicate many slots of one packed
    ciphertext in a single stacked launch.

    The per-slot loop (`bk.broadcast_slot` per key) pays one mul_plain
    plus a full log2(n) rotate-add reduction *per key* — it dominated
    translate launch counts.  Stacking nparent copies of `packed`
    against an (nparent, slots) one-hot basis matrix runs the same ops
    on every lane of one batch: identical per-block op counts, noise
    and depth, ~nparent x fewer launches."""
    idxs = list(idxs)
    if len(idxs) == 1:
        return [bk.broadcast_slot(packed, int(idxs[0]))]
    basis = np.zeros((len(idxs), bk.slots), dtype=np.int64)
    basis[np.arange(len(idxs)), np.asarray(idxs, dtype=np.int64)] = 1
    batch = bk.stack_blocks([packed] * len(idxs))
    return bk.unstack_blocks(bk.sum_slots(bk.mul_plain(batch, basis)))


def _translate_down(bk, packed, fact_blocks: list, nparent: int,
                    per_key: list | None = None) -> list:
    """Shared FK scatter: sum_j EQ(fk, j+1) x broadcast(packed, j).
    The nparent per-key EQ circuits run in one fused launch (or arrive
    pre-evaluated from the workload cache's fk bank), and the nparent
    slot broadcasts of `packed` fuse into one stacked launch too."""
    batched = len(fact_blocks) > 1
    if per_key is None:
        per_key = _per_key_eq(bk, fact_blocks, nparent)
    pjs = broadcast_slots(bk, packed, range(nparent))  # encrypted bits/values
    out = None
    for j in range(nparent):
        e = bk.stack_blocks(per_key[j]) if batched else per_key[j][0]
        term = bk.mul(e, pjs[j])
        out = term if out is None else bk.add(out, term)
    return _unstacked(bk, out, batched)


def join_aggregate(bk, fact_table: EncryptedTable, fk: str, nparent: int,
                   value_blocks: list | None, extra_mask: list | None = None) -> list:
    """Fused JOIN+aggregate (the paper's memory optimization): for each
    parent key j return SUM(value | fk = j [and mask]) — |P| scalar
    ciphertexts, never materializing the joined table."""
    results = []
    masks = fk_masks(bk, fact_table, fk, nparent)
    for j in range(nparent):
        m = masks[j]
        if extra_mask is not None:
            m = mul_lists(bk, m, extra_mask)
        if value_blocks is None:
            results.append(count(bk, m))
        else:
            results.append(masked_sum(bk, value_blocks, m))
    return results
