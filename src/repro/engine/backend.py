"""HE execution backends for the query engine.

One operator implementation (engine/ops.py, core/compare.py) runs against
either backend through the same method surface:

  BFVBackend  — real RNS-BFV ciphertexts (core/bfv.py).  Used by tests and
                small benchmarks; every op is genuinely homomorphic.
  MockBackend — plaintext Z_t arrays with *identical* noise accounting,
                depth tracking and op counting.  Used for full-32K-row
                TPC-H benchmarks on CPU: the timing model multiplies op
                counts by per-op costs calibrated on the real backend.

Batched evaluation path
-----------------------
Both backends additionally operate on *block batches* — a whole column
of ciphertext blocks stacked on a leading axis (`CiphertextBatch` for
BFV, a (nblocks, slots) MockCipher for the mock).  `stack_blocks` /
`unstack_blocks` convert between the engine's block lists and the
batched handle; every arithmetic method accepts either form (and mixed
single × batch operands, which broadcast), so the comparison circuits in
core/compare.py evaluate an entire column per jitted call instead of one
Python iteration per block.  OpStats counting is per *block*, not per
call: an op on an 8-block batch charges 8, so refresh-free profiles are
identical to the looped path.  Two deliberate approximations exist when
blocks carry *non-uniform* noise: a batch tracks the conservative max
(never under-estimating), and a mid-circuit refresh hits the stacked
temporary rather than the stored column blocks — so refresh counts on
noise-exhausted plans may differ from the looped schedule (decrypted
results never do; see ROADMAP open items).

Both count operations in OpStats and track (noise, depth) per value, so
the planner's predictions are validated against the same model regardless
of backend.  A `refresh` (the paper's "bootstrapping" event: client-side
re-encryption in NSHEDB's trust model) triggers automatically whenever an
op would exhaust the invariant-noise budget — the unoptimized plans pay
these, the noise-optimized plans are expected to avoid them entirely.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from ..core.bfv import BFVContext, Ciphertext, CiphertextBatch, Keys
from ..core.encoder import BatchEncoder
from ..core.noise import NoiseModel, NoiseProfile, paper_profile
from ..core.params import HEParams


@dataclasses.dataclass
class OpStats:
    """Homomorphic-operation counters (the engine's "profile")."""

    mul: int = 0            # ct x ct multiply (incl. relinearization)
    mul_plain: int = 0      # ct x plaintext-vector multiply
    mul_scalar: int = 0     # ct x constant multiply (no NTT)
    add: int = 0            # ct +- ct / plain
    rotate: int = 0         # Galois rotation (incl. key switch)
    encrypt: int = 0
    decrypt: int = 0
    refresh: int = 0        # noise-budget exhaustion events ("bootstraps")
    max_depth: int = 0      # deepest multiplicative chain observed
    launches: int = 0       # primitive *calls* (a batched op over N blocks
                            # is 1 launch but charges N to the op counters)

    def clone(self) -> "OpStats":
        return dataclasses.replace(self)

    def merged(self, other: "OpStats") -> "OpStats":
        out = self.clone()
        for f in dataclasses.fields(OpStats):
            if f.name == "max_depth":
                out.max_depth = max(out.max_depth, other.max_depth)
            else:
                setattr(out, f.name, getattr(out, f.name) + getattr(other, f.name))
        return out

    def cost_seconds(self, costs: dict[str, float]) -> float:
        """Wall-clock model: sum(count * per-op seconds)."""
        return sum(getattr(self, k) * v for k, v in costs.items() if hasattr(self, k))

    def reset(self) -> None:
        for f in dataclasses.fields(OpStats):
            setattr(self, f.name, 0)


class _BackendBase:
    """Shared bookkeeping: budget checks, refresh policy, stats."""

    def __init__(self) -> None:
        self.stats = OpStats()
        self.auto_refresh = True   # refresh (count a bootstrap) on exhaustion
        self.refresh_log: list[str] = []
        from collections import Counter
        self.op_log = Counter()    # operator-level counts (eq/cmp/sum/...)

    # -- subclass must provide -------------------------------------------
    t: int
    slots: int
    model: NoiseModel

    def _nblocks(self, ct) -> int:
        """Blocks carried by a value: batches charge per-block stats."""
        raise NotImplementedError

    def _count(self, *cts) -> int:
        self.stats.launches += 1
        return max(self._nblocks(c) for c in cts)

    def _budget(self, noise: float) -> float:
        return self.model.budget(noise)

    def _maybe_refresh(self, ct, post_noise: float, what: str):
        """If the upcoming op would exhaust the budget, refresh `ct` first.

        Refreshes mutate the ciphertext IN PLACE: every plan-DAG edge that
        still references this value sees the refreshed version, exactly as
        a real engine bootstraps a value once (not per consumer)."""
        if self._budget(post_noise) > 0:
            return ct
        if not self.auto_refresh:
            raise RuntimeError(
                f"noise budget exhausted in {what} "
                f"(post-op budget {self._budget(post_noise):.1f} bits)")
        self.stats.refresh += self._nblocks(ct)
        self.refresh_log.append(what)
        self.refresh_inplace(ct)
        return ct

    def _track_depth(self, d: int) -> int:
        self.stats.max_depth = max(self.stats.max_depth, d)
        return d

    def levels_left(self, ct) -> int:
        noise = ct.noise if hasattr(ct, "noise") else ct
        return self.model.levels_left(noise)

    def ensure_levels(self, ct, levels: int):
        """Planned refresh (§2.1.1 'selectively apply bootstrapping'): if
        the ciphertext cannot absorb `levels` more multiplications, refresh
        it *once* here rather than thrashing mid-circuit."""
        if self.levels_left(ct) >= levels:
            return ct
        self.stats.refresh += self._nblocks(ct)
        self.refresh_log.append(f"planned(levels={levels})")
        self.refresh_inplace(ct)
        return ct

    # convenience aliases used by compare.py ------------------------------
    def sub_scalar(self, a, c: int):
        return self.add_scalar(a, -c % self.t)

    # shared slot-movement compositions ----------------------------------
    def sum_slots(self, a):
        """All slots <- total sum (log2(n) rotate+add, paper §4.2.2)."""
        out = a
        step = 1
        while step < self.slots // 2:
            out = self.add(out, self.rotate(out, step))
            step *= 2
        return self.add(out, self.swap_rows(out))

    def broadcast_slot(self, a, i: int):
        """Extract slot i then replicate everywhere (paper §2.1.6)."""
        basis = np.zeros(self.slots, dtype=np.int64)
        basis[i] = 1
        return self.sum_slots(self.mul_plain(a, basis))


# ---------------------------------------------------------------------------
# Real-ciphertext backend.
# ---------------------------------------------------------------------------

class BFVBackend(_BackendBase):
    def __init__(self, params: HEParams, seed: int = 0,
                 kernel_backend: str | None = None, interpret: bool | None = None):
        super().__init__()
        self.params = params
        self.t = params.t
        self.slots = params.n
        self.ctx = BFVContext(params, seed=seed,
                              backend=kernel_backend, interpret=interpret)
        self.keys: Keys = self.ctx.keygen()
        self.enc = BatchEncoder(params)
        self.model = self.ctx.noise_model
        self._depth: dict[int, int] = {}

    def _nblocks(self, ct) -> int:
        return ct.nblocks if isinstance(ct, CiphertextBatch) else 1

    # -- depth side-table (Ciphertext is a frozen-ish dataclass) ----------
    def _d(self, ct) -> int:
        return self._depth.get(id(ct), 0)

    def _set_d(self, ct, d: int):
        self._depth[id(ct)] = self._track_depth(d)
        return ct

    # -- block batching ---------------------------------------------------
    def stack_blocks(self, blocks: list) -> CiphertextBatch:
        """Stack a column's block list for one batched call (pure layout)."""
        batch = self.ctx.stack_cts(blocks)
        return self._set_d(batch, max(self._d(b) for b in blocks))

    def unstack_blocks(self, batch: CiphertextBatch) -> list:
        d = self._d(batch)
        return [self._set_d(ct, d) for ct in self.ctx.unstack_cts(batch)]

    def fold_blocks(self, batch: CiphertextBatch) -> Ciphertext:
        """Cross-block sum of a batch (the inter-block half of SUM/COUNT).
        Charges the same nblocks-1 adds as the sequential fold."""
        self.stats.add += max(batch.nblocks - 1, 0)
        self.stats.launches += 1
        return self._set_d(self.ctx.fold_add(batch), self._d(batch))

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> Ciphertext:
        self.stats.encrypt += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return self._set_d(self.ctx.encrypt(self.enc.encode(v), self.keys.pk), 0)

    def decrypt(self, ct) -> np.ndarray:
        self.stats.decrypt += self._nblocks(ct)
        polys = self.ctx.decrypt(ct, self.keys.sk)
        if isinstance(ct, CiphertextBatch):
            return np.stack([np.asarray(self.enc.decode(p)) for p in polys])
        return np.asarray(self.enc.decode(polys))

    def refresh(self, ct: Ciphertext) -> Ciphertext:
        """Client-side re-encryption (NSHEDB's trust model allows it; the
        engine's planner exists to make sure this is never reached)."""
        return self.encrypt(self.decrypt(ct))

    def refresh_inplace(self, ct) -> None:
        if isinstance(ct, CiphertextBatch):
            fresh = [self.refresh(b) for b in self.ctx.unstack_cts(ct)]
            batch = self.ctx.stack_cts(fresh)
            ct.data, ct.noise = batch.data, batch.noise
        else:
            fresh = self.refresh(ct)
            ct.data = fresh.data
            ct.noise = fresh.noise
        self._depth[id(ct)] = 0

    def budget(self, ct) -> float:
        return ct.budget

    def depth(self, ct) -> int:
        return self._d(ct)

    # -- ring ops ------------------------------------------------------------
    def add(self, a, b):
        self.stats.add += self._count(a, b)
        return self._set_d(self.ctx.add(a, b), max(self._d(a), self._d(b)))

    def sub(self, a, b):
        self.stats.add += self._count(a, b)
        return self._set_d(self.ctx.sub(a, b), max(self._d(a), self._d(b)))

    def neg(self, a):
        return self._set_d(self.ctx.neg(a), self._d(a))

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if self._budget(post) <= 0:
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(b, self.model.keyswitch(
                self.model.mul(a.noise, b.noise)), "mul")
        self.stats.mul += self._count(a, b)
        out = self.ctx.mul(a, b, self.keys.rlk)
        return self._set_d(out, max(self._d(a), self._d(b)) + 1)

    def mul_plain(self, a, vec):
        post = self.model.mul_plain(a.noise)
        a = self._maybe_refresh(a, post, "mul_plain")
        self.stats.mul_plain += self._count(a)
        poly = self.enc.encode(np.asarray(vec, dtype=np.int64) % self.t)
        return self._set_d(self.ctx.mul_plain(a, poly), self._d(a) + 1)

    def add_plain(self, a, vec):
        self.stats.add += self._count(a)
        poly = self.enc.encode(np.asarray(vec, dtype=np.int64) % self.t)
        return self._set_d(self.ctx.add_plain(a, poly), self._d(a))

    def mul_scalar(self, a, c: int):
        self.stats.mul_scalar += self._count(a)
        return self._set_d(self.ctx.mul_scalar(a, c), self._d(a))

    def add_scalar(self, a, c: int):
        self.stats.add += self._count(a)
        return self._set_d(self.ctx.add_scalar(a, c), self._d(a))

    def sub_from_scalar(self, c: int, a):
        self.stats.add += self._count(a)
        return self._set_d(self.ctx.sub_from_scalar(c, a), self._d(a))

    def dot_plain(self, cts: list, coeffs) -> Ciphertext:
        """sum_i coeffs[i] * cts[i] — the BSGS baby-step inner product.
        Same accounting as len(cts) mul_scalar + adds."""
        acc = None
        for ct, c in zip(cts, coeffs):
            c = int(c) % self.t
            if c == 0:
                continue
            term = self.mul_scalar(ct, c)
            acc = term if acc is None else self.add(acc, term)
        assert acc is not None
        return acc

    # -- data movement ---------------------------------------------------
    def rotate(self, a, step: int):
        """Rotate rows (2 x n/2 layout) left by step."""
        self.stats.rotate += bin(step % (self.slots // 2)).count("1") * self._count(a)
        return self._set_d(self.ctx.rotate_rows(a, step, self.keys.gks), self._d(a))

    def swap_rows(self, a):
        self.stats.rotate += self._count(a)
        return self._set_d(self.ctx.swap_rows(a, self.keys.gks), self._d(a))


# ---------------------------------------------------------------------------
# Mock backend: Z_t arrays, same accounting.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MockCipher:
    vec: np.ndarray          # (slots,) — or (nblocks, slots) for a batch
    noise: float             # analytic log2 |invariant noise|
    depth: int = 0

    def __post_init__(self):
        self.vec = np.asarray(self.vec, dtype=np.int64)


class MockBackend(_BackendBase):
    """Executes the operator DAG on plaintext arrays mod t while charging
    the exact same noise/ops as the BFV path.  The paper-scale profile
    (n=32768, k=30 limbs) is the default.

    `kernel_reduce=True` routes the data half of `sum_slots` through the
    Pallas rotate-reduce kernel (kernels/rotate_reduce) — one launch for
    all log2(n) doubling stages — while charging the identical
    rotate/add/noise accounting as the looped schedule."""

    def __init__(self, profile: NoiseProfile | None = None, *,
                 kernel_reduce: bool = False):
        super().__init__()
        self.profile = profile or paper_profile()
        self.t = self.profile.t
        self.slots = self.profile.n
        self.model = NoiseModel(self.profile)
        self.kernel_reduce = kernel_reduce

    def _nblocks(self, ct) -> int:
        return ct.vec.shape[0] if ct.vec.ndim == 2 else 1

    # -- block batching ---------------------------------------------------
    def stack_blocks(self, blocks: list) -> MockCipher:
        assert all(b.vec.ndim == 1 for b in blocks)
        return MockCipher(np.stack([b.vec for b in blocks]),
                          max(b.noise for b in blocks),
                          max(b.depth for b in blocks))

    def unstack_blocks(self, batch: MockCipher) -> list:
        return [MockCipher(batch.vec[i].copy(), batch.noise, batch.depth)
                for i in range(batch.vec.shape[0])]

    def fold_blocks(self, batch: MockCipher) -> MockCipher:
        nb = self._nblocks(batch)
        self.stats.add += max(nb - 1, 0)
        self.stats.launches += 1
        noise = batch.noise
        for _ in range(nb - 1):
            noise = self.model.add(noise, batch.noise)
        return MockCipher(batch.vec.sum(axis=0) % self.t, noise,
                          self._track_depth(batch.depth))

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> MockCipher:
        self.stats.encrypt += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher(v, self.model.fresh(), 0)

    def decrypt(self, ct: MockCipher) -> np.ndarray:
        self.stats.decrypt += self._nblocks(ct)
        return ct.vec.copy()

    def refresh(self, ct: MockCipher) -> MockCipher:
        return MockCipher(ct.vec.copy(), self.model.fresh(), 0)

    def refresh_inplace(self, ct: MockCipher) -> None:
        ct.noise = self.model.fresh()
        ct.depth = 0

    def budget(self, ct: MockCipher) -> float:
        return self.model.budget(ct.noise)

    def depth(self, ct: MockCipher) -> int:
        return ct.depth

    # -- ring ops ------------------------------------------------------------
    def add(self, a, b):
        self.stats.add += self._count(a, b)
        return MockCipher((a.vec + b.vec) % self.t,
                          self.model.add(a.noise, b.noise),
                          self._track_depth(max(a.depth, b.depth)))

    def sub(self, a, b):
        self.stats.add += self._count(a, b)
        return MockCipher((a.vec - b.vec) % self.t,
                          self.model.add(a.noise, b.noise),
                          self._track_depth(max(a.depth, b.depth)))

    def neg(self, a):
        return MockCipher((-a.vec) % self.t, a.noise, a.depth)

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if self._budget(post) <= 0:
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(
                b, self.model.keyswitch(self.model.mul(a.noise, b.noise)), "mul")
        self.stats.mul += self._count(a, b)
        return MockCipher((a.vec * b.vec) % self.t,
                          self.model.keyswitch(self.model.mul(a.noise, b.noise)),
                          self._track_depth(max(a.depth, b.depth) + 1))

    def mul_plain(self, a, vec):
        a = self._maybe_refresh(a, self.model.mul_plain(a.noise), "mul_plain")
        self.stats.mul_plain += self._count(a)
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher((a.vec * v) % self.t, self.model.mul_plain(a.noise),
                          self._track_depth(a.depth + 1))

    def add_plain(self, a, vec):
        self.stats.add += self._count(a)
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher((a.vec + v) % self.t, self.model.add(a.noise, a.noise), a.depth)

    def mul_scalar(self, a, c: int):
        self.stats.mul_scalar += self._count(a)
        return MockCipher((a.vec * (c % self.t)) % self.t,
                          self.model.mul_scalar(a.noise, c), a.depth)

    def add_scalar(self, a, c: int):
        self.stats.add += self._count(a)
        return MockCipher((a.vec + c) % self.t,
                          self.model.add(a.noise, a.noise), a.depth)

    def sub_from_scalar(self, c: int, a):
        self.stats.add += self._count(a)
        return MockCipher((c - a.vec) % self.t,
                          self.model.add(a.noise, a.noise), a.depth)

    def dot_plain(self, cts: list, coeffs) -> MockCipher:
        """Vectorized sum_i coeffs[i]*cts[i]; charged as the equivalent
        mul_scalar/add sequence so op counts stay backend-independent."""
        cs = np.asarray(coeffs, dtype=np.int64) % self.t
        nz = [i for i in range(len(cts)) if cs[i] != 0]
        assert nz, "all-zero dot"
        nb = self._count(*[cts[i] for i in nz])
        self.stats.mul_scalar += len(nz) * nb
        self.stats.add += max(0, len(nz) - 1) * nb
        out = None
        for i in nz:                       # products < 2^34, running sums
            term = cts[i].vec * cs[i]      # < 2^34 * 2^15 — exact int64
            out = term if out is None else out + term
        out = out % self.t
        noises = [self.model.mul_scalar(cts[i].noise, int(cs[i])) for i in nz]
        depth = max(cts[i].depth for i in nz)
        return MockCipher(out, self.model.add_many(noises), self._track_depth(depth))

    # -- data movement ---------------------------------------------------
    def rotate(self, a, step: int):
        """Row-rotation semantics matching the BFV 2 x n/2 slot layout."""
        self.stats.rotate += bin(step % (self.slots // 2)).count("1") * self._count(a)
        half = self.slots // 2
        vec = np.concatenate([np.roll(a.vec[..., :half], -step, axis=-1),
                              np.roll(a.vec[..., half:], -step, axis=-1)], axis=-1)
        return MockCipher(vec, self.model.rotate(a.noise), a.depth)

    def swap_rows(self, a):
        self.stats.rotate += self._count(a)
        half = self.slots // 2
        vec = np.concatenate([a.vec[..., half:], a.vec[..., :half]], axis=-1)
        return MockCipher(vec, self.model.rotate(a.noise), a.depth)

    def sum_slots(self, a):
        if not self.kernel_reduce:
            return super().sum_slots(a)
        # Pallas rotate-reduce kernel: one launch replaces the whole
        # doubling schedule.  Accounting replays the looped recurrence
        # v <- add(v, rotate(v)) so stats/noise stay bit-identical.
        from ..kernels.rotate_reduce.ops import rotate_reduce
        half = self.slots // 2
        steps = int(math.log2(half)) + 1            # log rotations + row swap
        nb = self._nblocks(a)
        self.stats.add += steps * nb
        self.stats.rotate += steps * nb
        self.stats.launches += 1
        noise = a.noise
        for _ in range(steps):
            noise = self.model.add(noise, self.model.rotate(noise))
        rows = a.vec.reshape(-1, half)              # (2*nb, half) half-rows
        red = np.asarray(rotate_reduce(rows, self.t), dtype=np.int64)
        red = red.reshape(-1, 2, half)
        total = (red[:, 0] + red[:, 1]) % self.t    # (nb, half) full sums
        vec = np.concatenate([total, total], axis=-1).reshape(a.vec.shape)
        return MockCipher(vec, noise, self._track_depth(a.depth))


Backend = Any  # duck type: BFVBackend | MockBackend
