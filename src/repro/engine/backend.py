"""HE execution backends for the query engine.

One operator implementation (engine/ops.py, core/compare.py) runs against
either backend through the same method surface:

  BFVBackend  — real RNS-BFV ciphertexts (core/bfv.py).  Used by tests and
                small benchmarks; every op is genuinely homomorphic.
  MockBackend — plaintext Z_t arrays with *identical* noise accounting,
                depth tracking and op counting.  Used for full-32K-row
                TPC-H benchmarks on CPU: the timing model multiplies op
                counts by per-op costs calibrated on the real backend.

Both count operations in OpStats and track (noise, depth) per value, so
the planner's predictions are validated against the same model regardless
of backend.  A `refresh` (the paper's "bootstrapping" event: client-side
re-encryption in NSHEDB's trust model) triggers automatically whenever an
op would exhaust the invariant-noise budget — the unoptimized plans pay
these, the noise-optimized plans are expected to avoid them entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from ..core.bfv import BFVContext, Ciphertext, Keys
from ..core.encoder import BatchEncoder
from ..core.noise import NoiseModel, NoiseProfile, paper_profile
from ..core.params import HEParams


@dataclasses.dataclass
class OpStats:
    """Homomorphic-operation counters (the engine's "profile")."""

    mul: int = 0            # ct x ct multiply (incl. relinearization)
    mul_plain: int = 0      # ct x plaintext-vector multiply
    mul_scalar: int = 0     # ct x constant multiply (no NTT)
    add: int = 0            # ct +- ct / plain
    rotate: int = 0         # Galois rotation (incl. key switch)
    encrypt: int = 0
    decrypt: int = 0
    refresh: int = 0        # noise-budget exhaustion events ("bootstraps")
    max_depth: int = 0      # deepest multiplicative chain observed

    def clone(self) -> "OpStats":
        return dataclasses.replace(self)

    def merged(self, other: "OpStats") -> "OpStats":
        out = self.clone()
        for f in dataclasses.fields(OpStats):
            if f.name == "max_depth":
                out.max_depth = max(out.max_depth, other.max_depth)
            else:
                setattr(out, f.name, getattr(out, f.name) + getattr(other, f.name))
        return out

    def cost_seconds(self, costs: dict[str, float]) -> float:
        """Wall-clock model: sum(count * per-op seconds)."""
        return sum(getattr(self, k) * v for k, v in costs.items() if hasattr(self, k))

    def reset(self) -> None:
        for f in dataclasses.fields(OpStats):
            setattr(self, f.name, 0)


class _BackendBase:
    """Shared bookkeeping: budget checks, refresh policy, stats."""

    def __init__(self) -> None:
        self.stats = OpStats()
        self.auto_refresh = True   # refresh (count a bootstrap) on exhaustion
        self.refresh_log: list[str] = []
        from collections import Counter
        self.op_log = Counter()    # operator-level counts (eq/cmp/sum/...)

    # -- subclass must provide -------------------------------------------
    t: int
    slots: int
    model: NoiseModel

    def _budget(self, noise: float) -> float:
        return self.model.budget(noise)

    def _maybe_refresh(self, ct, post_noise: float, what: str):
        """If the upcoming op would exhaust the budget, refresh `ct` first.

        Refreshes mutate the ciphertext IN PLACE: every plan-DAG edge that
        still references this value sees the refreshed version, exactly as
        a real engine bootstraps a value once (not per consumer)."""
        if self._budget(post_noise) > 0:
            return ct
        if not self.auto_refresh:
            raise RuntimeError(
                f"noise budget exhausted in {what} "
                f"(post-op budget {self._budget(post_noise):.1f} bits)")
        self.stats.refresh += 1
        self.refresh_log.append(what)
        self.refresh_inplace(ct)
        return ct

    def _track_depth(self, d: int) -> int:
        self.stats.max_depth = max(self.stats.max_depth, d)
        return d

    def levels_left(self, ct) -> int:
        noise = ct.noise if hasattr(ct, "noise") else ct
        return self.model.levels_left(noise)

    def ensure_levels(self, ct, levels: int):
        """Planned refresh (§2.1.1 'selectively apply bootstrapping'): if
        the ciphertext cannot absorb `levels` more multiplications, refresh
        it *once* here rather than thrashing mid-circuit."""
        if self.levels_left(ct) >= levels:
            return ct
        self.stats.refresh += 1
        self.refresh_log.append(f"planned(levels={levels})")
        self.refresh_inplace(ct)
        return ct

    # convenience aliases used by compare.py ------------------------------
    def sub_scalar(self, a, c: int):
        return self.add_scalar(a, -c % self.t)


# ---------------------------------------------------------------------------
# Real-ciphertext backend.
# ---------------------------------------------------------------------------

class BFVBackend(_BackendBase):
    def __init__(self, params: HEParams, seed: int = 0):
        super().__init__()
        self.params = params
        self.t = params.t
        self.slots = params.n
        self.ctx = BFVContext(params, seed=seed)
        self.keys: Keys = self.ctx.keygen()
        self.enc = BatchEncoder(params)
        self.model = self.ctx.noise_model
        self._depth: dict[int, int] = {}

    # -- depth side-table (Ciphertext is a frozen-ish dataclass) ----------
    def _d(self, ct: Ciphertext) -> int:
        return self._depth.get(id(ct), 0)

    def _set_d(self, ct: Ciphertext, d: int) -> Ciphertext:
        self._depth[id(ct)] = self._track_depth(d)
        return ct

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> Ciphertext:
        self.stats.encrypt += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return self._set_d(self.ctx.encrypt(self.enc.encode(v), self.keys.pk), 0)

    def decrypt(self, ct: Ciphertext) -> np.ndarray:
        self.stats.decrypt += 1
        return np.asarray(self.enc.decode(self.ctx.decrypt(ct, self.keys.sk)))

    def refresh(self, ct: Ciphertext) -> Ciphertext:
        """Client-side re-encryption (NSHEDB's trust model allows it; the
        engine's planner exists to make sure this is never reached)."""
        return self.encrypt(self.decrypt(ct))

    def refresh_inplace(self, ct: Ciphertext) -> None:
        fresh = self.refresh(ct)
        ct.data = fresh.data
        ct.noise = fresh.noise
        self._depth[id(ct)] = 0

    def budget(self, ct: Ciphertext) -> float:
        return ct.budget

    def depth(self, ct: Ciphertext) -> int:
        return self._d(ct)

    # -- ring ops ------------------------------------------------------------
    def add(self, a, b):
        self.stats.add += 1
        return self._set_d(self.ctx.add(a, b), max(self._d(a), self._d(b)))

    def sub(self, a, b):
        self.stats.add += 1
        return self._set_d(self.ctx.sub(a, b), max(self._d(a), self._d(b)))

    def neg(self, a):
        return self._set_d(self.ctx.neg(a), self._d(a))

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if self._budget(post) <= 0:
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(b, self.model.keyswitch(
                self.model.mul(a.noise, b.noise)), "mul")
        self.stats.mul += 1
        out = self.ctx.mul(a, b, self.keys.rlk)
        return self._set_d(out, max(self._d(a), self._d(b)) + 1)

    def mul_plain(self, a, vec):
        post = self.model.mul_plain(a.noise)
        a = self._maybe_refresh(a, post, "mul_plain")
        self.stats.mul_plain += 1
        poly = self.enc.encode(np.asarray(vec, dtype=np.int64) % self.t)
        return self._set_d(self.ctx.mul_plain(a, poly), self._d(a) + 1)

    def add_plain(self, a, vec):
        self.stats.add += 1
        poly = self.enc.encode(np.asarray(vec, dtype=np.int64) % self.t)
        return self._set_d(self.ctx.add_plain(a, poly), self._d(a))

    def mul_scalar(self, a, c: int):
        self.stats.mul_scalar += 1
        return self._set_d(self.ctx.mul_scalar(a, c), self._d(a))

    def add_scalar(self, a, c: int):
        self.stats.add += 1
        return self._set_d(self.ctx.add_scalar(a, c), self._d(a))

    def sub_from_scalar(self, c: int, a):
        self.stats.add += 1
        return self._set_d(self.ctx.sub_from_scalar(c, a), self._d(a))

    def dot_plain(self, cts: list, coeffs) -> Ciphertext:
        """sum_i coeffs[i] * cts[i] — the BSGS baby-step inner product.
        Same accounting as len(cts) mul_scalar + adds."""
        acc = None
        for ct, c in zip(cts, coeffs):
            c = int(c) % self.t
            if c == 0:
                continue
            term = self.mul_scalar(ct, c)
            acc = term if acc is None else self.add(acc, term)
        assert acc is not None
        return acc

    # -- data movement ---------------------------------------------------
    def rotate(self, a, step: int):
        """Rotate rows (2 x n/2 layout) left by step."""
        self.stats.rotate += bin(step % (self.slots // 2)).count("1")
        return self._set_d(self.ctx.rotate_rows(a, step, self.keys.gks), self._d(a))

    def swap_rows(self, a):
        self.stats.rotate += 1
        return self._set_d(self.ctx.swap_rows(a, self.keys.gks), self._d(a))

    def sum_slots(self, a):
        """All slots <- total sum (log2(n) rotate+add, paper §4.2.2)."""
        out = a
        step = 1
        while step < self.slots // 2:
            out = self.add(out, self.rotate(out, step))
            step *= 2
        return self.add(out, self.swap_rows(out))

    def broadcast_slot(self, a, i: int):
        """Extract slot i then replicate everywhere (paper §2.1.6)."""
        basis = np.zeros(self.slots, dtype=np.int64)
        basis[i] = 1
        return self.sum_slots(self.mul_plain(a, basis))


# ---------------------------------------------------------------------------
# Mock backend: Z_t arrays, same accounting.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MockCipher:
    vec: np.ndarray          # (slots,) int64 in [0, t)
    noise: float             # analytic log2 |invariant noise|
    depth: int = 0

    def __post_init__(self):
        self.vec = np.asarray(self.vec, dtype=np.int64)


class MockBackend(_BackendBase):
    """Executes the operator DAG on plaintext arrays mod t while charging
    the exact same noise/ops as the BFV path.  The paper-scale profile
    (n=32768, k=30 limbs) is the default."""

    def __init__(self, profile: NoiseProfile | None = None):
        super().__init__()
        self.profile = profile or paper_profile()
        self.t = self.profile.t
        self.slots = self.profile.n
        self.model = NoiseModel(self.profile)

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> MockCipher:
        self.stats.encrypt += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher(v, self.model.fresh(), 0)

    def decrypt(self, ct: MockCipher) -> np.ndarray:
        self.stats.decrypt += 1
        return ct.vec.copy()

    def refresh(self, ct: MockCipher) -> MockCipher:
        return MockCipher(ct.vec.copy(), self.model.fresh(), 0)

    def refresh_inplace(self, ct: MockCipher) -> None:
        ct.noise = self.model.fresh()
        ct.depth = 0

    def budget(self, ct: MockCipher) -> float:
        return self.model.budget(ct.noise)

    def depth(self, ct: MockCipher) -> int:
        return ct.depth

    # -- ring ops ------------------------------------------------------------
    def add(self, a, b):
        self.stats.add += 1
        return MockCipher((a.vec + b.vec) % self.t,
                          self.model.add(a.noise, b.noise),
                          self._track_depth(max(a.depth, b.depth)))

    def sub(self, a, b):
        self.stats.add += 1
        return MockCipher((a.vec - b.vec) % self.t,
                          self.model.add(a.noise, b.noise),
                          self._track_depth(max(a.depth, b.depth)))

    def neg(self, a):
        return MockCipher((-a.vec) % self.t, a.noise, a.depth)

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if self._budget(post) <= 0:
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(
                b, self.model.keyswitch(self.model.mul(a.noise, b.noise)), "mul")
        self.stats.mul += 1
        return MockCipher((a.vec * b.vec) % self.t,
                          self.model.keyswitch(self.model.mul(a.noise, b.noise)),
                          self._track_depth(max(a.depth, b.depth) + 1))

    def mul_plain(self, a, vec):
        a = self._maybe_refresh(a, self.model.mul_plain(a.noise), "mul_plain")
        self.stats.mul_plain += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher((a.vec * v) % self.t, self.model.mul_plain(a.noise),
                          self._track_depth(a.depth + 1))

    def add_plain(self, a, vec):
        self.stats.add += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher((a.vec + v) % self.t, self.model.add(a.noise, a.noise), a.depth)

    def mul_scalar(self, a, c: int):
        self.stats.mul_scalar += 1
        return MockCipher((a.vec * (c % self.t)) % self.t,
                          self.model.mul_scalar(a.noise, c), a.depth)

    def add_scalar(self, a, c: int):
        self.stats.add += 1
        return MockCipher((a.vec + c) % self.t,
                          self.model.add(a.noise, a.noise), a.depth)

    def sub_from_scalar(self, c: int, a):
        self.stats.add += 1
        return MockCipher((c - a.vec) % self.t,
                          self.model.add(a.noise, a.noise), a.depth)

    def dot_plain(self, cts: list, coeffs) -> MockCipher:
        """Vectorized sum_i coeffs[i]*cts[i]; charged as the equivalent
        mul_scalar/add sequence so op counts stay backend-independent."""
        cs = np.asarray(coeffs, dtype=np.int64) % self.t
        nz = [i for i in range(len(cts)) if cs[i] != 0]
        assert nz, "all-zero dot"
        self.stats.mul_scalar += len(nz)
        self.stats.add += max(0, len(nz) - 1)
        out = np.zeros(self.slots, dtype=np.int64)
        for i in nz:                       # in-place FMA; products < 2^34,
            out += cts[i].vec * cs[i]      # sums < 2^34 * 2^15 — exact int64
        out %= self.t
        noises = [self.model.mul_scalar(cts[i].noise, int(cs[i])) for i in nz]
        depth = max(cts[i].depth for i in nz)
        return MockCipher(out, self.model.add_many(noises), self._track_depth(depth))

    # -- data movement ---------------------------------------------------
    def rotate(self, a, step: int):
        """Row-rotation semantics matching the BFV 2 x n/2 slot layout."""
        self.stats.rotate += bin(step % (self.slots // 2)).count("1")
        half = self.slots // 2
        vec = np.concatenate([np.roll(a.vec[:half], -step), np.roll(a.vec[half:], -step)])
        return MockCipher(vec, self.model.rotate(a.noise), a.depth)

    def swap_rows(self, a):
        self.stats.rotate += 1
        half = self.slots // 2
        vec = np.concatenate([a.vec[half:], a.vec[:half]])
        return MockCipher(vec, self.model.rotate(a.noise), a.depth)

    def sum_slots(self, a):
        out = a
        step = 1
        while step < self.slots // 2:
            out = self.add(out, self.rotate(out, step))
            step *= 2
        return self.add(out, self.swap_rows(out))

    def broadcast_slot(self, a, i: int):
        basis = np.zeros(self.slots, dtype=np.int64)
        basis[i] = 1
        return self.sum_slots(self.mul_plain(a, basis))


Backend = Any  # duck type: BFVBackend | MockBackend
