"""HE execution backends for the query engine.

One operator implementation (engine/ops.py, core/compare.py) runs against
either backend through the same method surface:

  BFVBackend  — real RNS-BFV ciphertexts (core/bfv.py).  Used by tests and
                small benchmarks; every op is genuinely homomorphic.
  MockBackend — plaintext Z_t arrays with *identical* noise accounting,
                depth tracking and op counting.  Used for full-32K-row
                TPC-H benchmarks on CPU: the timing model multiplies op
                counts by per-op costs calibrated on the real backend.

Batched evaluation path
-----------------------
Both backends additionally operate on *block batches* — a whole column
of ciphertext blocks stacked on a leading axis (`CiphertextBatch` for
BFV, a (nblocks, slots) MockCipher for the mock).  `stack_blocks` /
`unstack_blocks` convert between the engine's block lists and the
batched handle; every arithmetic method accepts either form (and mixed
single × batch operands, which broadcast), so the comparison circuits in
core/compare.py evaluate an entire column per jitted call instead of one
Python iteration per block.  OpStats counting is per *block*, not per
call: an op on an 8-block batch charges 8, so refresh-free profiles are
identical to the looped path.  Batches with *non-uniform* block noise
carry a per-block noise vector, and `_maybe_refresh`/`ensure_levels`
refresh only the exhausted lanes — matching the looped schedule's
refresh counts.  One approximation remains: a mid-circuit refresh hits
the stacked temporary rather than the stored column blocks (decrypted
results never differ).

Sharded execution (engine/sharded.py, DESIGN §4): when a ShardContext
is active on the backend, `stack_blocks` pads lane counts to a multiple
of the shard count with zero blocks (`live` on the batch keeps stats,
noise and decrypt on the logical count), `fold_blocks` reduces
shard-local partials with a psum collective when a real mesh is
attached, and every charge is mirrored into the context's
distributed/replicated cost ledger for scaling projections.

Both count operations in OpStats and track (noise, depth) per value, so
the planner's predictions are validated against the same model regardless
of backend.  A `refresh` (the paper's "bootstrapping" event: client-side
re-encryption in NSHEDB's trust model) triggers automatically whenever an
op would exhaust the invariant-noise budget — the unoptimized plans pay
these, the noise-optimized plans are expected to avoid them entirely.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from ..core.bfv import BFVContext, Ciphertext, CiphertextBatch, Keys
from ..runtime import faults
from ..core.encoder import BatchEncoder
from ..core.noise import NoiseModel, NoiseProfile, paper_profile
from ..core.params import HEParams


@dataclasses.dataclass
class OpStats:
    """Homomorphic-operation counters (the engine's "profile")."""

    mul: int = 0            # ct x ct multiply (incl. relinearization)
    mul_plain: int = 0      # ct x plaintext-vector multiply
    mul_scalar: int = 0     # ct x constant multiply (no NTT)
    add: int = 0            # ct +- ct / plain
    rotate: int = 0         # Galois rotation (incl. key switch)
    encrypt: int = 0
    decrypt: int = 0
    refresh: int = 0        # noise-budget exhaustion events ("bootstraps")
    max_depth: int = 0      # deepest multiplicative chain observed
    launches: int = 0       # primitive *calls* (a batched op over N blocks
                            # is 1 launch but charges N to the op counters)

    def clone(self) -> "OpStats":
        return dataclasses.replace(self)

    def merged(self, other: "OpStats") -> "OpStats":
        out = self.clone()
        for f in dataclasses.fields(OpStats):
            if f.name == "max_depth":
                out.max_depth = max(out.max_depth, other.max_depth)
            else:
                setattr(out, f.name, getattr(out, f.name) + getattr(other, f.name))
        return out

    def cost_seconds(self, costs: dict[str, float]) -> float:
        """Wall-clock model: sum(count * per-op seconds)."""
        return sum(getattr(self, k) * v for k, v in costs.items() if hasattr(self, k))

    def reset(self) -> None:
        for f in dataclasses.fields(OpStats):
            setattr(self, f.name, 0)


class _BackendBase:
    """Shared bookkeeping: budget checks, refresh policy, stats."""

    def __init__(self) -> None:
        self.stats = OpStats()
        self.auto_refresh = True   # refresh (count a bootstrap) on exhaustion
        self.refresh_log: list[str] = []
        # Active ShardContext (engine/sharded.py) or None.  When set,
        # stack_blocks pads lane counts to the shard count and every
        # charge is mirrored into the context's distribution ledger.
        self.shard_ctx = None
        from collections import Counter
        self.op_log = Counter()    # operator-level counts (eq/cmp/sum/...)

    # -- subclass must provide -------------------------------------------
    t: int
    slots: int
    model: NoiseModel

    def _nblocks(self, ct) -> int:
        """Blocks carried by a value: batches charge per-block stats.
        Reports *live* blocks — shard padding lanes are never counted."""
        raise NotImplementedError

    def _nblocks_phys(self, ct) -> int:
        """Physical lanes incl. shard padding (device-time accounting)."""
        return self._nblocks(ct)

    def _count(self, *cts) -> int:
        self.stats.launches += 1
        return max(self._nblocks(c) for c in cts)

    def _charge_units(self, field: str, units: int,
                      phys_units: int | None = None,
                      distributed: bool = False) -> None:
        """Charge `units` to stats.<field>; mirror into the shard ledger
        (physical units — pads occupy device lanes) when one is active."""
        setattr(self.stats, field, getattr(self.stats, field) + units)
        if self.shard_ctx is not None and units:
            self.shard_ctx.record(
                field, phys_units if phys_units is not None else units,
                distributed)

    def _charge(self, field: str, *cts, mult: int = 1) -> None:
        """The standard per-op charge: one launch, max-blocks units."""
        units = self._count(*cts) * mult
        phys = max(self._nblocks_phys(c) for c in cts) * mult
        dist = any(self._nblocks_phys(c) > 1 for c in cts)
        self._charge_units(field, units, phys, dist)

    def _charge_gather(self, *cts, mult: int = 1) -> None:
        """Mirror a key-switch digit all-gather into the 2-D shard
        ledger (model-axis bytes, ShardContext.record_gather): one unit
        per physical block lane per key-switch.  No-op at limb_shards=1,
        so 1-D ledgers stay byte-identical; never touches OpStats, so
        op counts stay backend- and mesh-independent."""
        ctx = self.shard_ctx
        if ctx is None or getattr(ctx, "limb_shards", 1) <= 1 or mult <= 0:
            return
        ctx.record_gather(max(self._nblocks_phys(c) for c in cts) * mult)

    def _budget(self, noise):
        return self.model.budget(noise)

    def _refresh_lanes(self, ct, exhausted) -> "list[int] | None":
        """Lanes of `ct` to refresh given an elementwise exhaustion mask.
        None means 'all of it' (scalar noise, or every lane exhausted)."""
        if np.ndim(ct.noise) == 0 or self._nblocks(ct) == 1:
            return None
        mask = np.broadcast_to(np.asarray(exhausted), (self._nblocks(ct),))
        lanes = [i for i in range(self._nblocks(ct)) if mask[i]]
        return None if len(lanes) == self._nblocks(ct) else lanes

    def _charge_refresh(self, ct, lanes, what: str) -> None:
        n = self._nblocks(ct) if lanes is None else len(lanes)
        self._charge_units("refresh", n, n, self._nblocks_phys(ct) > 1)
        self.refresh_log.append(what)

    def _maybe_refresh(self, ct, post_noise, what: str):
        """If the upcoming op would exhaust the budget, refresh `ct` first.

        Refreshes mutate the ciphertext IN PLACE: every plan-DAG edge that
        still references this value sees the refreshed version, exactly as
        a real engine bootstraps a value once (not per consumer).  With a
        per-block noise vector, only the exhausted lanes are refreshed.
        """
        exhausted = np.asarray(self._budget(post_noise)) <= 0
        if not exhausted.any():
            return ct
        if not self.auto_refresh:
            raise RuntimeError(
                f"noise budget exhausted in {what} "
                f"(post-op budget {float(np.min(self._budget(post_noise))):.1f} bits)")
        lanes = self._refresh_lanes(ct, exhausted)
        self._charge_refresh(ct, lanes, what)
        self.refresh_inplace(ct, lanes)
        return ct

    def _track_depth(self, d: int) -> int:
        self.stats.max_depth = max(self.stats.max_depth, d)
        return d

    def set_depth(self, ct, d: int) -> None:
        """Restore a handle's tracked multiplicative chain length after
        noise maintenance that must stay depth-neutral (the planner's
        inject admission).  Never raises the run's max-depth watermark."""
        ct.depth = d

    def fingerprint(self, ct) -> int | None:
        """Content hash of a ciphertext handle for at-rest integrity
        checks (WorkloadCache poison detection), or None when handles
        are opaque.  Real BFV returns None: `refresh_inplace`
        re-encrypts the payload under fresh randomness, so no stable
        content hash can survive legitimate noise maintenance."""
        return None

    def levels_left(self, ct) -> int:
        noise = ct.noise if hasattr(ct, "noise") else ct
        return self.model.levels_left(noise)

    def ensure_levels(self, ct, levels: int):
        """Planned refresh (§2.1.1 'selectively apply bootstrapping'): if
        the ciphertext cannot absorb `levels` more multiplications, refresh
        it *once* here rather than thrashing mid-circuit.  Per-block noise
        vectors refresh only the lanes that are actually short."""
        what = f"planned(levels={levels})"
        if np.ndim(ct.noise) and self._nblocks(ct) > 1:
            per = np.asarray(ct.noise)
            short = np.array([self.model.levels_left(float(per[i])) < levels
                              for i in range(self._nblocks(ct))])
            if not short.any():
                return ct
            lanes = self._refresh_lanes(ct, short)
            self._charge_refresh(ct, lanes, what)
            self.refresh_inplace(ct, lanes)
            return ct
        if self.levels_left(ct) >= levels:
            return ct
        self._charge_refresh(ct, None, what)
        self.refresh_inplace(ct, None)
        return ct

    # convenience aliases used by compare.py ------------------------------
    def sub_scalar(self, a, c: int):
        return self.add_scalar(a, -c % self.t)

    # shared slot-movement compositions ----------------------------------
    def sum_slots(self, a):
        """All slots <- total sum (log2(n) rotate+add, paper §4.2.2)."""
        out = a
        step = 1
        while step < self.slots // 2:
            out = self.add(out, self.rotate(out, step))
            step *= 2
        return self.add(out, self.swap_rows(out))

    def broadcast_slot(self, a, i: int):
        """Extract slot i then replicate everywhere (paper §2.1.6)."""
        basis = np.zeros(self.slots, dtype=np.int64)
        basis[i] = 1
        return self.sum_slots(self.mul_plain(a, basis))


# ---------------------------------------------------------------------------
# Real-ciphertext backend.
# ---------------------------------------------------------------------------

class BFVBackend(_BackendBase):
    def __init__(self, params: HEParams, seed: int = 0,
                 kernel_backend: str | None = None, interpret: bool | None = None):
        super().__init__()
        self.params = params
        self.t = params.t
        self.slots = params.n
        self.ctx = BFVContext(params, seed=seed,
                              backend=kernel_backend, interpret=interpret)
        self.keys: Keys = self.ctx.keygen()
        self.enc = BatchEncoder(params)
        self.model = self.ctx.noise_model
        self.limbs = params.k          # RNS tower height (model-axis extent)
        self._depth: dict[int, int] = {}

    def _limb_mesh(self):
        """The active context's 2-D mesh iff key-switches should
        all-gather over a real model axis (engine/sharded.py)."""
        ctx = self.shard_ctx
        return ctx.limb_mesh if ctx is not None else None

    def _nblocks(self, ct) -> int:
        return ct.nblocks if isinstance(ct, CiphertextBatch) else 1

    def _nblocks_phys(self, ct) -> int:
        return ct.nphys if isinstance(ct, CiphertextBatch) else 1

    # -- depth side-table (Ciphertext is a frozen-ish dataclass) ----------
    def _d(self, ct) -> int:
        return self._depth.get(id(ct), 0)

    def _set_d(self, ct, d: int):
        self._depth[id(ct)] = self._track_depth(d)
        return ct

    # -- block batching ---------------------------------------------------
    def stack_blocks(self, blocks: list) -> CiphertextBatch:
        """Stack a column's block list for one batched call (pure layout).

        Under an active ShardContext the lane count is padded up to a
        multiple of the shard count with zero blocks (exact additive
        identities; `live` keeps accounting on the logical count) and
        the batch is placed across the mesh "data" axis when a real
        mesh is attached — uneven tables compile to one even launch."""
        batch = self.ctx.stack_cts(blocks)
        ctx = self.shard_ctx
        if (ctx is not None and len(blocks) > 1
                and (ctx.shards > 1 or ctx.limb_mesh is not None)):
            from .sharded import pad_to, place_batch
            import jax.numpy as jnp
            nphys = pad_to(len(blocks), ctx.shards)
            data = batch.data
            if nphys > len(blocks):
                pad = jnp.zeros_like(batch.data[:1])
                data = jnp.concatenate(
                    [batch.data] + [pad] * (nphys - len(blocks)))
            if ctx.mesh is not None:
                data = place_batch(data, ctx.mesh)
            batch = CiphertextBatch(data, batch.noise, batch.params,
                                    live=len(blocks))
        return self._set_d(batch, max(self._d(b) for b in blocks))

    def unstack_blocks(self, batch: CiphertextBatch) -> list:
        d = self._d(batch)
        return [self._set_d(ct, d) for ct in self.ctx.unstack_cts(batch)]

    def fold_blocks(self, batch: CiphertextBatch) -> Ciphertext:
        """Cross-block sum of a batch (the inter-block half of SUM/COUNT).
        Charges the same nblocks-1 adds as the sequential fold.  With a
        real scan mesh attached the reduction runs shard-local and
        combines partials with a psum collective (engine/sharded.py)."""
        faults.maybe_device_loss("fold")
        ctx = self.shard_ctx
        self.stats.add += max(batch.nblocks - 1, 0)
        self.stats.launches += 1
        if ctx is not None:
            # ledger: shard-local adds + one psum tree (record_fold owns
            # the split; stats.add above stays the sequential-fold charge)
            ctx.record_fold(batch.nblocks, self._nblocks_phys(batch))
        if (ctx is not None and ctx.mesh is not None
                and batch.nphys % ctx.shards == 0 and batch.nphys > 1):
            from .sharded import sharded_fold
            from ..core.bfv import Ciphertext as _Ct
            raw = sharded_fold(batch.data, batch.nblocks, ctx.mesh)
            data = raw % self.ctx.qQ[:, None]
            per = batch.noise if np.ndim(batch.noise) else None
            noise = float(per[0]) if per is not None else batch.noise
            for i in range(1, batch.nblocks):
                noise = self.model.add(
                    noise, float(per[i]) if per is not None else batch.noise)
            out = _Ct(data, noise, batch.params)
        else:
            out = self.ctx.fold_add(batch)
        return self._set_d(out, self._d(batch))

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> Ciphertext:
        self.stats.encrypt += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return self._set_d(self.ctx.encrypt(self.enc.encode(v), self.keys.pk), 0)

    def decrypt(self, ct) -> np.ndarray:
        self.stats.decrypt += self._nblocks(ct)
        polys = self.ctx.decrypt(ct, self.keys.sk)
        if isinstance(ct, CiphertextBatch):
            # live lanes only: shard padding never reaches the client
            return np.stack([np.asarray(self.enc.decode(polys[i]))
                             for i in range(ct.nblocks)])
        return np.asarray(self.enc.decode(polys))

    def refresh(self, ct: Ciphertext) -> Ciphertext:
        """Client-side re-encryption (NSHEDB's trust model allows it; the
        engine's planner exists to make sure this is never reached)."""
        return self.encrypt(self.decrypt(ct))

    def refresh_inplace(self, ct, lanes: list | None = None) -> None:
        if isinstance(ct, CiphertextBatch):
            if lanes is not None:
                # partial: refresh only the exhausted lanes of the batch
                per = (np.asarray(ct.noise, dtype=np.float64).copy()
                       if np.ndim(ct.noise)
                       else np.full(ct.nblocks, float(ct.noise)))
                data = ct.data
                for i in lanes:
                    fb = self.refresh(Ciphertext(ct.data[i], float(per[i]),
                                                 self.params))
                    data = data.at[i].set(fb.data)
                    per[i] = fb.noise
                ct.data, ct.noise = data, self.ctx.pack_noises(list(per))
                return  # depth unchanged: un-refreshed lanes keep history
            fresh = [self.refresh(b) for b in self.ctx.unstack_cts(ct)]
            batch = self.ctx.stack_cts(fresh)
            if ct.nphys > batch.nphys:  # padded: keep the zero pad lanes
                ct.data = ct.data.at[:batch.nphys].set(batch.data)
                ct.noise = batch.noise
            else:
                ct.data, ct.noise = batch.data, batch.noise
        else:
            fresh = self.refresh(ct)
            ct.data = fresh.data
            ct.noise = fresh.noise
        self._depth[id(ct)] = 0

    def budget(self, ct) -> float:
        return ct.budget

    def depth(self, ct) -> int:
        return self._d(ct)

    def set_depth(self, ct, d: int) -> None:
        self._depth[id(ct)] = d

    # -- ring ops ------------------------------------------------------------
    def add(self, a, b):
        self._charge("add", a, b)
        return self._set_d(self.ctx.add(a, b), max(self._d(a), self._d(b)))

    def sub(self, a, b):
        self._charge("add", a, b)
        return self._set_d(self.ctx.sub(a, b), max(self._d(a), self._d(b)))

    def neg(self, a):
        return self._set_d(self.ctx.neg(a), self._d(a))

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if np.any(np.asarray(self._budget(post)) <= 0):
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(b, self.model.keyswitch(
                self.model.mul(a.noise, b.noise)), "mul")
        self._charge("mul", a, b)
        self._charge_gather(a, b)
        out = self.ctx.mul(a, b, self.keys.rlk, mesh=self._limb_mesh())
        return self._set_d(out, max(self._d(a), self._d(b)) + 1)

    def mul_plain(self, a, vec):
        post = self.model.mul_plain(a.noise)
        a = self._maybe_refresh(a, post, "mul_plain")
        self._charge("mul_plain", a)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        if arr.ndim == 2:
            # per-block plaintexts against a batch (fused broadcast_slot):
            # zero rows cover any shard padding lanes
            nphys = self._nblocks_phys(a)
            rows = np.zeros((nphys, self.slots), dtype=np.int64)
            rows[: arr.shape[0], : arr.shape[1]] = arr
            poly = np.stack([np.asarray(self.enc.encode(r)) for r in rows])
        else:
            poly = self.enc.encode(arr)
        return self._set_d(self.ctx.mul_plain(a, poly), self._d(a) + 1)

    def add_plain(self, a, vec):
        self._charge("add", a)
        poly = self.enc.encode(np.asarray(vec, dtype=np.int64) % self.t)
        return self._set_d(self.ctx.add_plain(a, poly), self._d(a))

    def mul_scalar(self, a, c: int):
        self._charge("mul_scalar", a)
        return self._set_d(self.ctx.mul_scalar(a, c), self._d(a))

    def add_scalar(self, a, c: int):
        self._charge("add", a)
        return self._set_d(self.ctx.add_scalar(a, c), self._d(a))

    def sub_from_scalar(self, c: int, a):
        self._charge("add", a)
        return self._set_d(self.ctx.sub_from_scalar(c, a), self._d(a))

    def dot_plain(self, cts: list, coeffs) -> Ciphertext:
        """sum_i coeffs[i] * cts[i] — the BSGS baby-step inner product.
        Same accounting as len(cts) mul_scalar + adds."""
        acc = None
        for ct, c in zip(cts, coeffs):
            c = int(c) % self.t
            if c == 0:
                continue
            term = self.mul_scalar(ct, c)
            acc = term if acc is None else self.add(acc, term)
        assert acc is not None
        return acc

    # -- data movement ---------------------------------------------------
    def rotate(self, a, step: int):
        """Rotate rows (2 x n/2 layout) left by step."""
        hops = bin(step % (self.slots // 2)).count("1")
        self._charge("rotate", a, mult=hops)
        self._charge_gather(a, mult=hops)      # one kswitch per pow-2 hop
        return self._set_d(
            self.ctx.rotate_rows(a, step, self.keys.gks,
                                 mesh=self._limb_mesh()), self._d(a))

    def swap_rows(self, a):
        self._charge("rotate", a)
        self._charge_gather(a)
        return self._set_d(
            self.ctx.swap_rows(a, self.keys.gks, mesh=self._limb_mesh()),
            self._d(a))


# ---------------------------------------------------------------------------
# Mock backend: Z_t arrays, same accounting.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MockCipher:
    vec: np.ndarray          # (slots,) — or (nblocks, slots) for a batch
    noise: "float | np.ndarray"   # log2 |invariant noise|, per-block if array
    depth: int = 0
    live: int | None = None  # logical blocks when shard-padded (see bfv.py)

    def __post_init__(self):
        self.vec = np.asarray(self.vec, dtype=np.int64)


class MockBackend(_BackendBase):
    """Executes the operator DAG on plaintext arrays mod t while charging
    the exact same noise/ops as the BFV path.  The paper-scale profile
    (n=32768, k=30 limbs) is the default.

    `kernel_reduce=True` routes the data half of `sum_slots` through the
    Pallas rotate-reduce kernel (kernels/rotate_reduce) — one launch for
    all log2(n) doubling stages — while charging the identical
    rotate/add/noise accounting as the looped schedule."""

    def __init__(self, profile: NoiseProfile | None = None, *,
                 kernel_reduce: bool = False):
        super().__init__()
        self.profile = profile or paper_profile()
        self.t = self.profile.t
        self.slots = self.profile.n
        self.model = NoiseModel(self.profile)
        self.limbs = self.profile.k    # RNS tower height (model-axis extent)
        self.kernel_reduce = kernel_reduce

    def _nblocks(self, ct) -> int:
        if ct.vec.ndim != 2:
            return 1
        return ct.live if ct.live is not None else ct.vec.shape[0]

    def _nblocks_phys(self, ct) -> int:
        return ct.vec.shape[0] if ct.vec.ndim == 2 else 1

    @staticmethod
    def _live(*cts) -> int | None:
        """live marker the result of an op inherits (batched operand's)."""
        for c in cts:
            if c.vec.ndim == 2 and c.live is not None:
                return c.live
        return None

    @staticmethod
    def _pack_noises(noises: list) -> "float | np.ndarray":
        vals = [float(v) for v in noises]
        if all(v == vals[0] for v in vals):
            return vals[0]
        return np.asarray(vals, dtype=np.float64)

    # -- block batching ---------------------------------------------------
    def stack_blocks(self, blocks: list) -> MockCipher:
        assert all(b.vec.ndim == 1 for b in blocks)
        vec = np.stack([b.vec for b in blocks])
        live = None
        ctx = self.shard_ctx
        if ctx is not None and ctx.shards > 1 and len(blocks) > 1:
            from .sharded import pad_to
            nphys = pad_to(len(blocks), ctx.shards)
            if nphys > len(blocks):
                vec = np.concatenate(
                    [vec, np.zeros((nphys - len(blocks), self.slots),
                                   dtype=np.int64)])
            live = len(blocks)
        return MockCipher(vec, self._pack_noises([b.noise for b in blocks]),
                          max(b.depth for b in blocks), live)

    def unstack_blocks(self, batch: MockCipher) -> list:
        per = batch.noise if np.ndim(batch.noise) else None
        return [MockCipher(batch.vec[i].copy(),
                           float(per[i]) if per is not None else batch.noise,
                           batch.depth)
                for i in range(self._nblocks(batch))]

    def fold_blocks(self, batch: MockCipher) -> MockCipher:
        faults.maybe_device_loss("fold")
        nb = self._nblocks(batch)
        self.stats.add += max(nb - 1, 0)
        self.stats.launches += 1
        if self.shard_ctx is not None:
            self.shard_ctx.record_fold(nb, self._nblocks_phys(batch))
        per = batch.noise if np.ndim(batch.noise) else None
        noise = float(per[0]) if per is not None else batch.noise
        for i in range(1, nb):
            noise = self.model.add(
                noise, float(per[i]) if per is not None else batch.noise)
        # live lanes only: pads may hold garbage after broadcasted ops
        return MockCipher(batch.vec[:nb].sum(axis=0) % self.t, noise,
                          self._track_depth(batch.depth))

    # -- io ----------------------------------------------------------------
    def encrypt(self, vec) -> MockCipher:
        self.stats.encrypt += 1
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher(v, self.model.fresh(), 0)

    def decrypt(self, ct: MockCipher) -> np.ndarray:
        self.stats.decrypt += self._nblocks(ct)
        if ct.vec.ndim == 2:
            return ct.vec[: self._nblocks(ct)].copy()
        return ct.vec.copy()

    def refresh(self, ct: MockCipher) -> MockCipher:
        return MockCipher(ct.vec.copy(), self.model.fresh(), 0, ct.live)

    def refresh_inplace(self, ct: MockCipher, lanes: list | None = None) -> None:
        if lanes is not None and np.ndim(ct.noise):
            per = np.asarray(ct.noise, dtype=np.float64).copy()
            per[lanes] = self.model.fresh()
            ct.noise = self._pack_noises(list(per))
            return  # depth unchanged: un-refreshed lanes keep history
        ct.noise = self.model.fresh()
        ct.depth = 0

    def budget(self, ct: MockCipher) -> float:
        return self.model.min_budget(ct.noise)

    def depth(self, ct: MockCipher) -> int:
        return ct.depth

    def fingerprint(self, ct: MockCipher) -> int:
        """Mock handles expose stable content: every op builds a new
        MockCipher and `refresh_inplace` rewrites only noise/depth, so
        the vec hash changes iff the payload was tampered with."""
        return faults.crc_array(ct.vec)

    # -- ring ops ------------------------------------------------------------
    def add(self, a, b):
        self._charge("add", a, b)
        return MockCipher((a.vec + b.vec) % self.t,
                          self.model.add(a.noise, b.noise),
                          self._track_depth(max(a.depth, b.depth)),
                          self._live(a, b))

    def sub(self, a, b):
        self._charge("add", a, b)
        return MockCipher((a.vec - b.vec) % self.t,
                          self.model.add(a.noise, b.noise),
                          self._track_depth(max(a.depth, b.depth)),
                          self._live(a, b))

    def neg(self, a):
        return MockCipher((-a.vec) % self.t, a.noise, a.depth, self._live(a))

    def mul(self, a, b):
        post = self.model.keyswitch(self.model.mul(a.noise, b.noise))
        if np.any(np.asarray(self._budget(post)) <= 0):
            a = self._maybe_refresh(a, post, "mul")
            b = self._maybe_refresh(
                b, self.model.keyswitch(self.model.mul(a.noise, b.noise)), "mul")
        self._charge("mul", a, b)
        self._charge_gather(a, b)
        return MockCipher((a.vec * b.vec) % self.t,
                          self.model.keyswitch(self.model.mul(a.noise, b.noise)),
                          self._track_depth(max(a.depth, b.depth) + 1),
                          self._live(a, b))

    def mul_plain(self, a, vec):
        a = self._maybe_refresh(a, self.model.mul_plain(a.noise), "mul_plain")
        self._charge("mul_plain", a)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        if arr.ndim == 2:
            # per-block plaintexts against a batch (fused broadcast_slot):
            # zero rows cover any shard padding lanes
            v = np.zeros((self._nblocks_phys(a), self.slots), dtype=np.int64)
            v[: arr.shape[0], : arr.shape[1]] = arr
        else:
            v = np.zeros(self.slots, dtype=np.int64)
            v[: len(arr)] = arr
        return MockCipher((a.vec * v) % self.t, self.model.mul_plain(a.noise),
                          self._track_depth(a.depth + 1), self._live(a))

    def add_plain(self, a, vec):
        self._charge("add", a)
        v = np.zeros(self.slots, dtype=np.int64)
        arr = np.asarray(vec, dtype=np.int64) % self.t
        v[: len(arr)] = arr
        return MockCipher((a.vec + v) % self.t, self.model.add(a.noise, a.noise),
                          a.depth, self._live(a))

    def mul_scalar(self, a, c: int):
        self._charge("mul_scalar", a)
        return MockCipher((a.vec * (c % self.t)) % self.t,
                          self.model.mul_scalar(a.noise, c), a.depth,
                          self._live(a))

    def add_scalar(self, a, c: int):
        self._charge("add", a)
        return MockCipher((a.vec + c) % self.t,
                          self.model.add(a.noise, a.noise), a.depth,
                          self._live(a))

    def sub_from_scalar(self, c: int, a):
        self._charge("add", a)
        return MockCipher((c - a.vec) % self.t,
                          self.model.add(a.noise, a.noise), a.depth,
                          self._live(a))

    def dot_plain(self, cts: list, coeffs) -> MockCipher:
        """Vectorized sum_i coeffs[i]*cts[i]; charged as the equivalent
        mul_scalar/add sequence so op counts stay backend-independent."""
        cs = np.asarray(coeffs, dtype=np.int64) % self.t
        nz = [i for i in range(len(cts)) if cs[i] != 0]
        assert nz, "all-zero dot"
        used = [cts[i] for i in nz]
        nb = self._count(*used)
        phys = max(self._nblocks_phys(c) for c in used)
        dist = any(self._nblocks_phys(c) > 1 for c in used)
        self._charge_units("mul_scalar", len(nz) * nb, len(nz) * phys, dist)
        self._charge_units("add", max(0, len(nz) - 1) * nb,
                           max(0, len(nz) - 1) * phys, dist)
        out = None
        for i in nz:                       # products < 2^34, running sums
            term = cts[i].vec * cs[i]      # < 2^34 * 2^15 — exact int64
            out = term if out is None else out + term
        out = out % self.t
        noises = [self.model.mul_scalar(cts[i].noise, int(cs[i])) for i in nz]
        depth = max(cts[i].depth for i in nz)
        return MockCipher(out, self.model.add_many(noises),
                          self._track_depth(depth), self._live(*used))

    # -- data movement ---------------------------------------------------
    def rotate(self, a, step: int):
        """Row-rotation semantics matching the BFV 2 x n/2 slot layout."""
        hops = bin(step % (self.slots // 2)).count("1")
        self._charge("rotate", a, mult=hops)
        self._charge_gather(a, mult=hops)
        half = self.slots // 2
        vec = np.concatenate([np.roll(a.vec[..., :half], -step, axis=-1),
                              np.roll(a.vec[..., half:], -step, axis=-1)], axis=-1)
        return MockCipher(vec, self.model.rotate(a.noise), a.depth, self._live(a))

    def swap_rows(self, a):
        self._charge("rotate", a)
        self._charge_gather(a)
        half = self.slots // 2
        vec = np.concatenate([a.vec[..., half:], a.vec[..., :half]], axis=-1)
        return MockCipher(vec, self.model.rotate(a.noise), a.depth, self._live(a))

    def sum_slots(self, a):
        if not self.kernel_reduce:
            return super().sum_slots(a)
        # Pallas rotate-reduce kernel: one launch replaces the whole
        # doubling schedule.  Accounting replays the looped recurrence
        # v <- add(v, rotate(v)) so stats/noise stay bit-identical.
        from ..kernels.rotate_reduce.ops import rotate_reduce
        half = self.slots // 2
        steps = int(math.log2(half)) + 1            # log rotations + row swap
        nb = self._nblocks(a)
        phys = self._nblocks_phys(a)
        dist = phys > 1
        self._charge_units("add", steps * nb, steps * phys, dist)
        self._charge_units("rotate", steps * nb, steps * phys, dist)
        self._charge_gather(a, mult=steps)     # ledger parity w/ looped path
        self.stats.launches += 1
        noise = a.noise
        for _ in range(steps):
            noise = self.model.add(noise, self.model.rotate(noise))
        rows = a.vec.reshape(-1, half)              # (2*nb, half) half-rows
        red = np.asarray(rotate_reduce(rows, self.t), dtype=np.int64)
        red = red.reshape(-1, 2, half)
        total = (red[:, 0] + red[:, 1]) % self.t    # (nb, half) full sums
        vec = np.concatenate([total, total], axis=-1).reshape(a.vec.shape)
        return MockCipher(vec, noise, self._track_depth(a.depth), self._live(a))


Backend = Any  # duck type: BFVBackend | MockBackend
