"""Sharded scan execution over ciphertext blocks (DESIGN §4).

Scan-first execution is embarrassingly parallel across ciphertext
blocks: a stacked column is a `(nblocks, 2, k, n)` batch, and every
mask-evaluation / combination / plaintext-mul step is block-local.
This module makes that parallelism explicit:

* `ShardContext` — the per-run distribution plan.  It carries the shard
  count, an optional real `("data",)` mesh (launch/mesh.py:
  make_scan_mesh), and a cost ledger that splits every charged op into
  *distributed* units (lanes of a multi-block batch — these divide by
  the shard count) vs *replicated* units (singleton ciphertexts and
  post-fold reductions — these run on every shard or on one) plus the
  psum-style fold collectives.  `modeled_seconds(costs)` prices the
  ledger with measured per-op costs, which is how
  `benchmarks/sharded_scan.py` produces SF=1.0 scaling curves on the
  mock backend.

* `activate(bk, ctx)` — installs the context on a backend for the
  duration of an execution.  While active, `stack_blocks` pads the lane
  count up to a multiple of `ctx.shards` with zero blocks (uneven
  tables compile to one even launch; `CiphertextBatch.live` records the
  logical count so fold/unstack/decrypt ignore the pads), batches are
  device_put with a `("data", ...)` NamedSharding when a real mesh is
  present, and every `OpStats` charge is mirrored into the ledger.

* `sharded_fold(data, live, mesh)` — the one step that genuinely needs
  a collective: the block-fold reduction runs shard-local over each
  shard's lanes and combines partial sums with `jax.lax.psum` over
  "data".  Pad lanes are excluded with a 0/1 lane-weight vector so the
  whole thing stays a single launch.  The shard_map body runs under
  `limbops.force_ref()` because Pallas interpret mode cannot trace
  inside a shard_map region.

Parity contract: padding lanes are exact additive identities for the
fold and are never decrypted, `_count`/`_nblocks` keep returning *live*
lane counts, and noise accounting never sees the pads — so OpStats,
noise trajectories, refresh schedules and decrypted outputs are
byte-identical to the single-device path (tests/test_sharded_exec.py).
"""
from __future__ import annotations

import contextlib
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ..core import limbops
from ..launch.mesh import make_scan_mesh
from ..runtime.elastic import elastic_scan_plan


def pad_to(nblocks: int, shards: int) -> int:
    """Lane count after padding nblocks up to a multiple of shards."""
    if shards <= 1 or nblocks <= 1:
        return nblocks
    return nblocks + (-nblocks) % shards


class ShardContext:
    """Distribution plan + cost ledger for one sharded execution."""

    def __init__(self, shards: int, mesh=None):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.shards = int(shards)
        self.mesh = mesh
        # op -> units that run data-parallel over the shard axis
        # (physical lanes of multi-block batches, pads included — pads
        # occupy a device lane even though OpStats never count them).
        self.dist: dict[str, float] = {}
        # op -> units with no block axis to shard (singletons, folded
        # aggregates, refreshes of single blocks) — serial time.
        self.repl: dict[str, float] = {}
        self.folds = 0  # cross-shard psum collectives issued

    def record(self, field: str, units: float, distributed: bool) -> None:
        ledger = self.dist if distributed else self.repl
        ledger[field] = ledger.get(field, 0) + units

    def record_fold(self, live: int, phys: int) -> None:
        """A block-fold: shard-local adds + one psum tree combine."""
        local = max(phys - self.shards, 0) if self.shards > 1 else max(phys - 1, 0)
        if local:
            self.dist["add"] = self.dist.get("add", 0) + local
        self.folds += 1

    def modeled_seconds(self, costs: dict) -> float:
        """Price the ledger: distributed time divides by the shard
        count, replicated time and the psum combine tree do not."""
        dist = sum(n * costs.get(op, 0.0) for op, n in self.dist.items())
        repl = sum(n * costs.get(op, 0.0) for op, n in self.repl.items())
        tree = math.ceil(math.log2(self.shards)) if self.shards > 1 else 0
        coll = self.folds * tree * costs.get("add", 0.0)
        return dist / self.shards + repl + coll

    def heartbeats(self, costs: dict, slowdowns: dict | None = None,
                   baseline: float = 0.0) -> dict:
        """Per-worker synthetic step times from the cost ledger.

        The sharded scan is bulk-synchronous: every worker carries an
        equal share of the distributed units plus the replicated tail,
        so the modeled per-run seconds *are* each worker's step time.
        `slowdowns` scales individual workers (real hardware skew, or
        an injected straggler — runtime/faults.py); `baseline` subtracts
        a prior `modeled_seconds` snapshot so a heartbeat reflects one
        execution, not the context's lifetime.  The executor feeds these
        to StragglerDetector.report after every sharded run.
        """
        step = max(self.modeled_seconds(costs) - baseline, 0.0)
        slow = slowdowns or {}
        return {w: step * float(slow.get(w, 1.0)) for w in range(self.shards)}

    def ledger_snapshot(self) -> dict:
        return {"shards": self.shards, "dist": dict(self.dist),
                "repl": dict(self.repl), "folds": self.folds,
                "real_mesh": self.mesh is not None}

    def reshard(self, excluded) -> "ShardContext":
        """Shrink onto the surviving workers after straggler exclusion."""
        plan = elastic_scan_plan(self.shards, excluded)
        return make_shard_context(plan["shards"])

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"ShardContext(shards={self.shards}, "
                f"mesh={'real' if self.mesh is not None else None}, "
                f"folds={self.folds})")


def make_shard_context(shards: int, mesh="auto") -> ShardContext:
    """Build a context; 'auto' attaches a real mesh when the host has
    enough devices (e.g. under XLA_FLAGS=--xla_force_host_platform_
    device_count=8), else runs logical-only (padding + ledger, single
    device) so shard plans stay testable on one chip."""
    if mesh == "auto":
        mesh = make_scan_mesh(shards) if 1 < shards <= len(jax.devices()) else None
    return ShardContext(shards, mesh)


@contextlib.contextmanager
def activate(bk, ctx: ShardContext | None):
    """Install ctx as bk.shard_ctx for the duration.  Reentrant: if the
    same context is already active this is a no-op, so nested scopes
    (executor -> evaluator flush) do not double-install."""
    prev = getattr(bk, "shard_ctx", None)
    if ctx is None or prev is ctx:
        yield prev
        return
    bk.shard_ctx = ctx
    try:
        yield ctx
    finally:
        bk.shard_ctx = prev


def batch_sharding(mesh):
    """NamedSharding placing the leading block axis on "data"."""
    spec = jax.sharding.PartitionSpec("data", None, None, None)
    return jax.sharding.NamedSharding(mesh, spec)


def place_batch(data, mesh):
    """device_put a (nblocks, 2, k, n) batch across the scan mesh."""
    return jax.device_put(data, batch_sharding(mesh))


@functools.partial(jax.jit, static_argnames=("mesh",))
def _fold_psum(data, weights, *, mesh):
    P = jax.sharding.PartitionSpec

    def body(d, w):
        local = jnp.sum(d * w[:, None, None, None], axis=0)
        return jax.lax.psum(local, "data")

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=P())(data, weights)


def sharded_fold(data, live: int, mesh):
    """Fold a padded (nphys, 2, k, n) batch: shard-local weighted sum,
    then psum over the "data" axis.  Returns the raw (2, k, n) sum —
    the caller reduces mod q (residues are < 2^30, so even ~190 int64
    partial sums cannot overflow before the reduction)."""
    nphys = data.shape[0]
    weights = (jnp.arange(nphys) < live).astype(data.dtype)
    with limbops.force_ref():
        return _fold_psum(data, weights, mesh=mesh)
