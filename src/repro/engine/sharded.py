"""Sharded query execution over ciphertext blocks and RNS limbs (DESIGN §4).

Two orthogonal axes of parallelism, mapped onto one 2-D
`("data", "model")` mesh (launch/mesh.py: make_query_mesh):

* **data** — scan-first execution is embarrassingly parallel across
  ciphertext blocks: a stacked column is a `(nblocks, 2, k, n)` batch,
  and every mask-evaluation / combination / plaintext-mul step is
  block-local.  Lanes partition over "data"; the block fold is the one
  collective (a psum).

* **model** — inside every block, the k RNS limbs are embarrassingly
  parallel for all pointwise mul/add and NTT work (core/limbops.py
  operates limb-by-limb), so limbs partition over "model" with zero
  communication — except key-switching (relinearization after a ct-ct
  multiply, and every Galois rotation), the only cross-limb step in
  core/bfv.py: each device all-gathers the centered decomposition
  digits along "model" before the gadget fold
  (core/bfv.py: kswitch_gathered).

This module owns the runtime plumbing:

* `ShardContext` — the per-run distribution plan.  It carries both axis
  sizes, an optional real mesh, and a 2-D cost ledger: *distributed*
  units (lanes of a multi-block batch — divide by the data-shard
  count), *replicated* units (singletons and post-fold reductions),
  fold collectives, and — new with the model axis — *limb-local* bytes
  (work that divides by the per-device limb count) vs *all-gather*
  bytes (key-switch digit movement across "model").
  `modeled_seconds(costs)` prices the ledger with measured per-op
  costs; the limb factor k / ceil(k/M) divides every limb-local term
  and the gather bytes pay `costs["gather_byte"]` seconds each.

* Limb padding: when `k % limb_shards != 0` the limb axis pads up to
  `limb_pad_to(k, M)` — padded limbs are pure ledger/placement
  entities (a real mesh is only attached when k divides evenly; the
  non-divisible case runs logical-only), so decrypt/OpStats stay
  byte-identical to single-device regardless of M.

* `activate(bk, ctx)` — installs the context on a backend for the
  duration of an execution.  While active, `stack_blocks` pads the lane
  count up to a multiple of `ctx.shards` with zero blocks (uneven
  tables compile to one even launch; `CiphertextBatch.live` records the
  logical count so fold/unstack/decrypt ignore the pads), batches are
  device_put with a `("data", None, "model", None)` NamedSharding when
  a real mesh is present, and every `OpStats` charge is mirrored into
  the ledger.

* `sharded_fold(data, live, mesh)` — the data-axis collective: the
  block-fold reduction runs shard-local over each shard's lanes and
  combines partial sums with `jax.lax.psum` over "data" (limb slices
  stay put — the fold is limb-local).  Pad lanes are excluded with a
  0/1 lane-weight vector so the whole thing stays a single launch.
  The shard_map body runs under `limbops.force_ref()` because Pallas
  interpret mode cannot trace inside a shard_map region.

Parity contract: padding lanes (block or limb) are exact additive
identities, `_count`/`_nblocks` keep returning *live* lane counts, and
noise accounting never sees the pads — so OpStats, noise trajectories,
refresh schedules and decrypted outputs are byte-identical to the
single-device path for every (shards, limb_shards) combination
(tests/test_sharded_exec.py, tests/test_limb_sharding.py).
"""
from __future__ import annotations

import contextlib
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ..core import limbops
from ..launch.mesh import make_query_mesh, make_scan_mesh
from ..runtime.elastic import elastic_limb_plan, elastic_scan_plan

# Modeled interconnect cost of moving one byte in a model-axis
# all-gather (~25 GB/s effective bisection — host-interconnect class).
# Benchmarks override via costs["gather_byte"]; at paper parameters a
# key-switch gather is ~0.3 ms/block against a ~15 s multiply, so the
# limb axis is compute-dominated by 4+ orders of magnitude.
GATHER_BYTE_SECONDS = 4e-11


def pad_to(nblocks: int, shards: int) -> int:
    """Lane count after padding nblocks up to a multiple of shards."""
    if shards <= 1 or nblocks <= 1:
        return nblocks
    return nblocks + (-nblocks) % shards


def limb_pad_to(limbs: int, limb_shards: int) -> int:
    """Limb count after padding k up to a multiple of the model axis.

    Unlike block lanes, a single limb still pads (every ciphertext has
    the full k-limb tower) — the pad limbs are ledger/placement
    entities only and never materialize in ciphertext data."""
    if limb_shards <= 1:
        return limbs
    return limbs + (-limbs) % limb_shards


class ShardContext:
    """2-D distribution plan + cost ledger for one sharded execution."""

    def __init__(self, shards: int, mesh=None, limb_shards: int = 1,
                 limbs: int | None = None, ring_n: int = 0):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if limb_shards < 1:
            raise ValueError(f"limb_shards must be >= 1, got {limb_shards}")
        self.shards = int(shards)          # data axis
        self.limb_shards = int(limb_shards)  # model axis
        self.limbs = limbs                 # k of the backend's RNS tower
        self.ring_n = int(ring_n)          # polynomial degree n
        self.mesh = mesh
        # op -> units that run data-parallel over the shard axis
        # (physical lanes of multi-block batches, pads included — pads
        # occupy a device lane even though OpStats never count them).
        self.dist: dict[str, float] = {}
        # op -> units with no block axis to shard (singletons, folded
        # aggregates, refreshes of single blocks) — serial time.
        self.repl: dict[str, float] = {}
        self.folds = 0         # cross-shard psum collectives issued
        self.gathers = 0       # model-axis key-switch all-gathers issued
        self.gather_bytes = 0.0      # digit bytes moved across "model"
        self.limb_local_bytes = 0.0  # op bytes that stayed limb-local

    # ----------------------------------------------------------- geometry
    @property
    def workers(self) -> int:
        """Flattened worker count: id = data_row * limb_shards + limb."""
        return self.shards * self.limb_shards

    @property
    def limb_mesh(self):
        """The mesh iff it carries a real model axis to key-switch over."""
        if (self.mesh is not None and self.limb_shards > 1
                and "model" in self.mesh.axis_names):
            return self.mesh
        return None

    def limb_factor(self) -> float:
        """Speedup of limb-local work: k over the padded per-device limb
        count, k / ceil(k/M) — exactly M when M divides k, less when
        padding wastes device rows (k=30, M=4 -> 30/8 = 3.75x)."""
        if self.limb_shards <= 1:
            return 1.0
        if not self.limbs:
            return float(self.limb_shards)
        kpad = limb_pad_to(self.limbs, self.limb_shards)
        return self.limbs / (kpad // self.limb_shards)

    def _block_bytes(self) -> int:
        """Device bytes of one (2, kpad, n) int64 block (pads occupy
        device rows, matching the physical-lane ledger philosophy)."""
        if not self.limbs or not self.ring_n:
            return 0
        return 2 * limb_pad_to(self.limbs, self.limb_shards) * self.ring_n * 8

    def _digit_bytes(self) -> int:
        """Bytes of one (kpad, n) int64 centered-digit polynomial — the
        payload a key-switch all-gathers along the model axis."""
        if not self.limbs or not self.ring_n:
            return 0
        return limb_pad_to(self.limbs, self.limb_shards) * self.ring_n * 8

    # ------------------------------------------------------------- ledger
    def record(self, field: str, units: float, distributed: bool) -> None:
        ledger = self.dist if distributed else self.repl
        ledger[field] = ledger.get(field, 0) + units
        self.limb_local_bytes += units * self._block_bytes()

    def record_fold(self, live: int, phys: int) -> None:
        """A block-fold: shard-local adds + one psum tree combine."""
        local = max(phys - self.shards, 0) if self.shards > 1 else max(phys - 1, 0)
        if local:
            self.dist["add"] = self.dist.get("add", 0) + local
            self.limb_local_bytes += local * self._block_bytes()
        self.folds += 1

    def record_gather(self, units: float) -> None:
        """A key-switch digit all-gather over "model": each unit moves
        one block's (kpad, n) centered-digit polynomial.  Only called
        when limb_shards > 1 — at M=1 there is nothing to gather and
        the ledger must price identically to the 1-D context."""
        self.gathers += 1
        self.gather_bytes += units * self._digit_bytes()

    def modeled_seconds(self, costs: dict) -> float:
        """Price the ledger: distributed time divides by the data-shard
        count AND the limb factor (every op is limb-local), replicated
        time divides by the limb factor alone, the psum tree moves
        limb-sharded payloads, and the gather bytes pay the model-axis
        interconnect — each device already holds its own 1/M slice, so
        only (M-1)/M of every gathered byte crosses the wire."""
        lf = self.limb_factor()
        dist = sum(n * costs.get(op, 0.0) for op, n in self.dist.items())
        repl = sum(n * costs.get(op, 0.0) for op, n in self.repl.items())
        tree = math.ceil(math.log2(self.shards)) if self.shards > 1 else 0
        coll = self.folds * tree * costs.get("add", 0.0)
        gather = (self.gather_bytes
                  * costs.get("gather_byte", GATHER_BYTE_SECONDS)
                  * (self.limb_shards - 1) / max(self.limb_shards, 1))
        return dist / (self.shards * lf) + repl / lf + coll / lf + gather

    def heartbeats(self, costs: dict, slowdowns: dict | None = None,
                   baseline: float = 0.0) -> dict:
        """Per-worker synthetic step times from the cost ledger.

        The sharded scan is bulk-synchronous: every worker carries an
        equal share of the distributed units plus the replicated tail,
        so the modeled per-run seconds *are* each worker's step time.
        Workers enumerate the flattened 2-D grid — id = data_row *
        limb_shards + limb_col — so a straggling chip shows up on
        exactly one (row, column) coordinate.  `slowdowns` scales
        individual workers (real hardware skew, or an injected
        straggler — runtime/faults.py); `baseline` subtracts a prior
        `modeled_seconds` snapshot so a heartbeat reflects one
        execution, not the context's lifetime.  The executor feeds
        these to StragglerDetector.report after every sharded run.
        """
        step = max(self.modeled_seconds(costs) - baseline, 0.0)
        slow = slowdowns or {}
        return {w: step * float(slow.get(w, 1.0)) for w in range(self.workers)}

    def ledger_snapshot(self) -> dict:
        return {"shards": self.shards, "limb_shards": self.limb_shards,
                "dist": dict(self.dist), "repl": dict(self.repl),
                "folds": self.folds, "gathers": self.gathers,
                "gather_bytes": self.gather_bytes,
                "limb_local_bytes": self.limb_local_bytes,
                "limb_factor": self.limb_factor(),
                "real_mesh": self.mesh is not None}

    def reshard(self, excluded, axis: str = "data") -> "ShardContext":
        """Shrink one mesh axis onto the surviving workers after
        straggler exclusion; the other axis is preserved.  `excluded`
        holds data-row ids for axis="data", limb-column ids for
        axis="model"."""
        if axis == "model":
            plan = elastic_limb_plan(self.limb_shards, excluded,
                                     limbs=self.limbs)
            return make_shard_context(self.shards,
                                      limb_shards=plan["limb_shards"],
                                      limbs=self.limbs, ring_n=self.ring_n)
        plan = elastic_scan_plan(self.shards, excluded)
        return make_shard_context(plan["shards"],
                                  limb_shards=self.limb_shards,
                                  limbs=self.limbs, ring_n=self.ring_n)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"ShardContext(shards={self.shards}, "
                f"limb_shards={self.limb_shards}, "
                f"mesh={'real' if self.mesh is not None else None}, "
                f"folds={self.folds}, gathers={self.gathers})")


def make_shard_context(shards: int, mesh="auto", limb_shards: int = 1,
                       limbs: int | None = None,
                       ring_n: int = 0) -> ShardContext:
    """Build a context; 'auto' attaches a real mesh when the host has
    enough devices (e.g. under XLA_FLAGS=--xla_force_host_platform_
    device_count=8), else runs logical-only (padding + ledger, single
    device) so shard plans stay testable on one chip.

    The model axis gets real device placement only when the limb count
    divides evenly (k % M == 0) — otherwise limb sharding stays a
    ledger/padding model (the data axis may still get a real 1-D mesh),
    keeping device arithmetic byte-exact with no materialized pad limbs.
    """
    if mesh == "auto":
        ndev = len(jax.devices())
        real_limb_axis = (limb_shards > 1 and limbs is not None
                          and limbs % limb_shards == 0)
        if real_limb_axis and shards * limb_shards <= ndev:
            mesh = make_query_mesh(shards, limb_shards)
        elif 1 < shards <= ndev:
            mesh = make_scan_mesh(shards)
        else:
            mesh = None
    return ShardContext(shards, mesh, limb_shards=limb_shards,
                        limbs=limbs, ring_n=ring_n)


def lint_shard_context(ctx: ShardContext, limbs: int | None = None,
                       ring_n: int = 0) -> list:
    """Static placement lint (engine/verify.py): check a shard context's
    2-D geometry against the backend it will execute on.  Returns
    (code, message) tuples; empty means the placement is consistent.

    Rules: the context's recorded RNS tower / ring degree must match the
    backend's; a *real* model axis requires k % M == 0 (the limb-padding
    rule — padded limbs are ledger-only entities and must never get
    device placement); and a real mesh's axis extents must match the
    declared shard counts."""
    out = []
    if limbs is not None and ctx.limbs is not None and ctx.limbs != limbs:
        out.append(("mesh.limbs",
                    f"context RNS tower k={ctx.limbs} != backend k={limbs} "
                    f"— gather-byte and limb-factor accounting would be "
                    f"priced for the wrong ciphertext geometry"))
    if ring_n and ctx.ring_n and ctx.ring_n != ring_n:
        out.append(("mesh.ring",
                    f"context ring_n={ctx.ring_n} != backend slots={ring_n}"))
    if (ctx.limb_mesh is not None and ctx.limbs is not None
            and ctx.limbs % ctx.limb_shards != 0):
        out.append(("mesh.pad",
                    f"real model axis with k={ctx.limbs} % M="
                    f"{ctx.limb_shards} != 0 — padded limbs must stay "
                    f"ledger-only, never device-placed"))
    if ctx.mesh is not None:
        shape = dict(getattr(ctx.mesh, "shape", None) or {})
        if "data" in shape and shape["data"] != ctx.shards:
            out.append(("mesh.data",
                        f"mesh data axis has {shape['data']} devices, "
                        f"context declares shards={ctx.shards}"))
        if "model" in shape and shape["model"] != ctx.limb_shards:
            out.append(("mesh.model",
                        f"mesh model axis has {shape['model']} devices, "
                        f"context declares limb_shards={ctx.limb_shards}"))
    return out


@contextlib.contextmanager
def activate(bk, ctx: ShardContext | None):
    """Install ctx as bk.shard_ctx for the duration.  Reentrant: if the
    same context is already active this is a no-op, so nested scopes
    (executor -> evaluator flush) do not double-install."""
    prev = getattr(bk, "shard_ctx", None)
    if ctx is None or prev is ctx:
        yield prev
        return
    bk.shard_ctx = ctx
    try:
        yield ctx
    finally:
        bk.shard_ctx = prev


def batch_sharding(mesh):
    """NamedSharding for a (nblocks, 2, k, n) batch: block lanes on
    "data", RNS limbs on "model" when the mesh carries that axis."""
    if "model" in mesh.axis_names:
        spec = jax.sharding.PartitionSpec("data", None, "model", None)
    else:
        spec = jax.sharding.PartitionSpec("data", None, None, None)
    return jax.sharding.NamedSharding(mesh, spec)


def place_batch(data, mesh):
    """device_put a (nblocks, 2, k, n) batch across the query mesh."""
    return jax.device_put(data, batch_sharding(mesh))


@functools.partial(jax.jit, static_argnames=("mesh",))
def _fold_psum(data, weights, *, mesh):
    P = jax.sharding.PartitionSpec
    limb = "model" if "model" in mesh.axis_names else None

    def body(d, w):
        local = jnp.sum(d * w[:, None, None, None], axis=0)
        return jax.lax.psum(local, "data")

    return shard_map(body, mesh=mesh,
                     in_specs=(P("data", None, limb, None), P("data")),
                     out_specs=P(None, limb, None))(data, weights)


def sharded_fold(data, live: int, mesh):
    """Fold a padded (nphys, 2, k, n) batch: shard-local weighted sum,
    then psum over the "data" axis; limb slices never move (the fold is
    limb-local, so a 2-D mesh keeps the result sharded over "model").
    Returns the raw (2, k, n) sum — the caller reduces mod q (residues
    are < 2^30, so even ~190 int64 partial sums cannot overflow before
    the reduction)."""
    nphys = data.shape[0]
    weights = (jnp.arange(nphys) < live).astype(data.dtype)
    with limbops.force_ref():
        return _fold_psum(data, weights, mesh=mesh)
