"""TPC-H-style dataset generator + schemas (paper §5.1).

The paper loads all eight TPC-H tables at SF-1 with LINEITEM sampled to
32K rows and related tables scaled proportionally, storing 16-bit integer
encodings (Fig. 7).  We generate a deterministic dataset with the same
shape: value domains fit in [0, t/2) for t = 65537, dates are day offsets
from 1992-01-01, strings are dictionary-encoded, decimals fixed-point.

`Scale` controls row counts so tests run the identical schema at tiny
sizes while benchmarks run the paper's 32K-row setting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .schema import ColumnSpec, TableSchema
from .storage import Database

REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]
NATIONS = [
    ("ALGERIA", 1), ("ARGENTINA", 2), ("BRAZIL", 2), ("CANADA", 2),
    ("EGYPT", 5), ("ETHIOPIA", 1), ("FRANCE", 4), ("GERMANY", 4),
    ("INDIA", 3), ("INDONESIA", 3), ("IRAN", 5), ("IRAQ", 5),
    ("JAPAN", 3), ("JORDAN", 5), ("KENYA", 1), ("MOROCCO", 1),
    ("MOZAMBIQUE", 1), ("PERU", 2), ("CHINA", 3), ("ROMANIA", 4),
    ("SAUDI ARABIA", 5), ("VIETNAM", 3), ("RUSSIA", 4),
    ("UNITED KINGDOM", 4), ("UNITED STATES", 2),
]
SEGMENTS = ["AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
SHIPMODES = ["AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK"]
SHIPINSTRUCT = ["COLLECT COD", "DELIVER IN PERSON", "NONE", "TAKE BACK RETURN"]
RETURNFLAGS = ["A", "N", "R"]
LINESTATUS = ["F", "O"]
BRANDS = [f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)]
CONTAINERS = [f"{s} {k}" for s in ("SM", "MED", "LG", "JUMBO", "WRAP")
              for k in ("BAG", "BOX", "CASE", "DRUM", "JAR", "PACK", "PKG", "CAN")]
TYPES = [f"{a} {b}" for a in ("ECONOMY", "STANDARD", "PROMO") for b in
         ("ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED")]


@dataclasses.dataclass(frozen=True)
class Scale:
    lineitem: int = 32768
    orders: int = 8192
    customer: int = 1024
    supplier: int = 256
    part: int = 1024
    partsupp: int = 2048

    @staticmethod
    def tiny() -> "Scale":
        """Test scale: full schema, hundreds of rows."""
        return Scale(lineitem=192, orders=48, customer=12, supplier=6,
                     part=16, partsupp=24)

    @staticmethod
    def small() -> "Scale":
        return Scale(lineitem=2048, orders=512, customer=64, supplier=16,
                     part=64, partsupp=128)


def schemas() -> dict[str, TableSchema]:
    C = ColumnSpec
    return {
        "region": TableSchema("region", [C("r_regionkey", "int"), C("r_name", "str")]),
        "nation": TableSchema("nation", [
            C("n_nationkey", "int"), C("n_name", "str"), C("n_regionkey", "int")]),
        "supplier": TableSchema("supplier", [
            C("s_suppkey", "int"), C("s_nationkey", "int")]),
        "customer": TableSchema("customer", [
            C("c_custkey", "int"), C("c_nationkey", "int"), C("c_mktsegment", "str")]),
        "part": TableSchema("part", [
            C("p_partkey", "int"), C("p_brand", "str"), C("p_type", "str"),
            C("p_container", "str"), C("p_size", "int")]),
        "partsupp": TableSchema("partsupp", [
            C("ps_partkey", "int"), C("ps_suppkey", "int"),
            C("ps_availqty", "int"), C("ps_supplycost", "decimal", scale=1)]),
        "orders": TableSchema("orders", [
            C("o_orderkey", "int"), C("o_custkey", "int"),
            C("o_orderdate", "date"), C("o_orderpriority", "str")]),
        "lineitem": TableSchema("lineitem", [
            C("l_orderkey", "int"), C("l_partkey", "int"), C("l_suppkey", "int"),
            C("l_quantity", "int"), C("l_extendedprice", "decimal", scale=1),
            C("l_discount", "decimal", scale=100), C("l_tax", "decimal", scale=100),
            C("l_returnflag", "flag"), C("l_linestatus", "flag"),
            C("l_shipdate", "date"), C("l_commitdate", "date"),
            C("l_receiptdate", "date"), C("l_shipinstruct", "str"),
            C("l_shipmode", "str")]),
    }


def generate(scale: Scale, seed: int = 7) -> dict[str, dict]:
    """Deterministic raw (pre-encoding) table data."""
    rng = np.random.default_rng(seed)
    sc = scale

    def pick(options, n):
        return [options[i] for i in rng.integers(0, len(options), n)]

    data: dict[str, dict] = {}
    data["region"] = {
        "r_regionkey": np.arange(1, 6), "r_name": REGIONS}
    data["nation"] = {
        "n_nationkey": np.arange(1, 26),
        "n_name": [n for n, _ in NATIONS],
        "n_regionkey": np.array([r for _, r in NATIONS])}
    data["supplier"] = {
        "s_suppkey": np.arange(1, sc.supplier + 1),
        "s_nationkey": rng.integers(1, 26, sc.supplier)}
    data["customer"] = {
        "c_custkey": np.arange(1, sc.customer + 1),
        "c_nationkey": rng.integers(1, 26, sc.customer),
        "c_mktsegment": pick(SEGMENTS, sc.customer)}
    data["part"] = {
        "p_partkey": np.arange(1, sc.part + 1),
        "p_brand": pick(BRANDS, sc.part),
        "p_type": pick(TYPES, sc.part),
        "p_container": pick(CONTAINERS, sc.part),
        "p_size": rng.integers(1, 51, sc.part)}
    data["partsupp"] = {
        "ps_partkey": rng.integers(1, sc.part + 1, sc.partsupp),
        "ps_suppkey": rng.integers(1, sc.supplier + 1, sc.partsupp),
        "ps_availqty": rng.integers(1, 10000, sc.partsupp),
        "ps_supplycost": rng.integers(1, 1000, sc.partsupp)}

    odate = rng.integers(1, 2401, sc.orders)          # 1992..1998 day offsets
    data["orders"] = {
        "o_orderkey": np.arange(1, sc.orders + 1),
        "o_custkey": rng.integers(1, sc.customer + 1, sc.orders),
        "o_orderdate": odate,                          # already day ints
        "o_orderpriority": pick(PRIORITIES, sc.orders)}

    lorder = rng.integers(1, sc.orders + 1, sc.lineitem)
    ship = odate[lorder - 1] + rng.integers(1, 122, sc.lineitem)
    commit = odate[lorder - 1] + rng.integers(30, 91, sc.lineitem)
    receipt = ship + rng.integers(1, 31, sc.lineitem)
    data["lineitem"] = {
        "l_orderkey": lorder,
        "l_partkey": rng.integers(1, sc.part + 1, sc.lineitem),
        "l_suppkey": rng.integers(1, sc.supplier + 1, sc.lineitem),
        "l_quantity": rng.integers(1, 51, sc.lineitem),
        "l_extendedprice": rng.integers(100, 10001, sc.lineitem),
        "l_discount": rng.integers(0, 11, sc.lineitem) / 100.0,
        "l_tax": rng.integers(0, 9, sc.lineitem) / 100.0,
        "l_returnflag": pick(RETURNFLAGS, sc.lineitem),
        "l_linestatus": pick(LINESTATUS, sc.lineitem),
        "l_shipdate": ship,
        "l_commitdate": commit,
        "l_receiptdate": receipt,
        "l_shipinstruct": pick(SHIPINSTRUCT, sc.lineitem),
        "l_shipmode": pick(SHIPMODES, sc.lineitem)}
    return data


_ROWCOUNT = {"region": 5, "nation": 25}


def load(backend, scale: Scale, seed: int = 7, tables: list[str] | None = None) -> Database:
    """Generate, encode and encrypt the dataset into a Database."""
    raw = generate(scale, seed)
    sch = schemas()
    db = Database(backend)
    for name, tdata in raw.items():
        if tables is not None and name not in tables:
            continue
        schema = sch[name]
        nrows = _ROWCOUNT.get(name) or len(next(iter(tdata.values())))
        db.load_table(schema, tdata, nrows)
    return db
