"""The paper's nine TPC-H benchmark queries (§5.1): Q1, 4, 5, 6, 8, 12,
14, 17, 19 — scan+aggregation, multi-way equi-joins, semi-/nested joins
and complex predicates.

Each query has:
  plan_qN()            declarative QueryPlan (drives the depth model and,
                       for the ported queries, compiled-DAG execution)
  run_qN(planner, ...) encrypted execution composed from engine.ops —
                       kept verbatim as the parity oracle for the
                       compiled path
  oracle_qN(db, ...)   plaintext reference (numpy over the client shadow
                       copies) returning the same mod-t values

Q1, Q6, Q12 and Q19 additionally execute through the physical operator
DAG: `run_via_plan(planner, plan_qN())` (engine/executor.py) lowers the
plan, fuses comparison circuits across columns, reuses mask subgraphs
via CSE, and must decrypt to exactly the same result as `run_qN`.

Aggregate results follow the paper's conventions: AVG is returned as a
(SUM, COUNT) pair; fixed-point scales multiply through products and the
client rescales after decryption; sums are mod-t (the engine also offers
ops.partial_sums for exact client-side reconstruction — see DESIGN.md).
"""
from __future__ import annotations

import numpy as np

from ..core import compare as cmp
from . import ops
from .executor import run_via_plan  # noqa: F401  (re-exported: the DAG path)
from .plan import (Agg, And, AuxMask, Factor, JoinHop, Or, Pred, QueryPlan,
                   Translated)
from .planner import Planner
from .schema import date_to_int
from .storage import Database

D = date_to_int


def _dec(bk, ct) -> int:
    return int(bk.decrypt(ct)[0])


def _dec_pair(bk, pair):
    return (_dec(bk, pair[0]), _dec(bk, pair[1]))


def _dict_of(db: Database, table: str, col: str) -> dict:
    return db.tables[table].schema.col(col).dictionary


# ===========================================================================
# Q1 — pricing summary report (scan + multi-column GROUP BY + aggregates).
# ===========================================================================

def plan_q1() -> QueryPlan:
    return QueryPlan(
        name="Q1", fact="lineitem",
        where=Pred("l_shipdate", "<=", D("1998-09-02")),
        group_by="l_returnflag,l_linestatus", group_domain=6,
        aggs=(
            Agg("sum", (Factor("l_quantity"),), "sum_qty"),
            Agg("sum", (Factor("l_extendedprice"),), "sum_base_price"),
            Agg("sum", (Factor("l_extendedprice"), Factor("l_discount", -1, 100)),
                "sum_disc_price"),
            Agg("sum", (Factor("l_extendedprice"), Factor("l_discount", -1, 100),
                        Factor("l_tax", 1, 100)), "sum_charge"),
            Agg("avg", (Factor("l_quantity"),), "avg_qty"),
            Agg("avg", (Factor("l_extendedprice"),), "avg_price"),
            Agg("avg", (Factor("l_discount"),), "avg_disc"),
            Agg("count", (), "count_order"),
        ),
        order_by="l_returnflag,l_linestatus")


def run_q1(pl: Planner, cutoff: str = "1998-09-02") -> dict:
    bk, db = pl.bk, pl.db
    li = db.tables["lineitem"]
    where = pl.where_mask(li, Pred("l_shipdate", "<=", D(cutoff)))
    rf_dict = _dict_of(db, "lineitem", "l_returnflag")
    ls_dict = _dict_of(db, "lineitem", "l_linestatus")
    plan = plan_q1()
    out = {}
    # ORDER BY rf, ls == enumerate dictionaries in sorted order (§4.2.3).
    for rf_name, rf_id in sorted(rf_dict.items()):
        rf_mask = [cmp.eq_scalar(bk, ct, rf_id) for ct in li.col("l_returnflag").blocks]
        for ls_name, ls_id in sorted(ls_dict.items()):
            ls_mask = [cmp.eq_scalar(bk, ct, ls_id) for ct in li.col("l_linestatus").blocks]
            if pl.optimized:
                gmask = ops.and_masks(bk, [rf_mask, ls_mask, where])
            else:
                gmask = ops.and_masks_seq(bk, [where, rf_mask, ls_mask])
            gmask = ops.apply_validity(bk, gmask, li)
            row = {}
            for agg in plan.aggs:
                r = pl._agg_with_mask(li, agg, gmask)
                row[agg.name] = _dec_pair(bk, r) if agg.kind == "avg" else _dec(bk, r)
            out[(rf_name, ls_name)] = row
    return out


def oracle_q1(db: Database, cutoff: str = "1998-09-02") -> dict:
    t = db.bk.t
    li = db.plain["lineitem"]
    sel = li["l_shipdate"] <= D(cutoff)
    out = {}
    rf_dict = _dict_of(db, "lineitem", "l_returnflag")
    ls_dict = _dict_of(db, "lineitem", "l_linestatus")
    for rf_name, rf_id in sorted(rf_dict.items()):
        for ls_name, ls_id in sorted(ls_dict.items()):
            m = sel & (li["l_returnflag"] == rf_id) & (li["l_linestatus"] == ls_id)
            price, qty = li["l_extendedprice"][m], li["l_quantity"][m]
            disc, tax = li["l_discount"][m], li["l_tax"][m]
            cnt = int(m.sum())
            out[(rf_name, ls_name)] = {
                "sum_qty": int(qty.sum()) % t,
                "sum_base_price": int(price.sum()) % t,
                "sum_disc_price": int((price * (100 - disc)).sum()) % t,
                "sum_charge": int((price * (100 - disc) % t * (100 + tax)).sum()) % t,
                "avg_qty": (int(qty.sum()) % t, cnt % t),
                "avg_price": (int(price.sum()) % t, cnt % t),
                "avg_disc": (int(disc.sum()) % t, cnt % t),
                "count_order": cnt % t,
            }
    return out


# ===========================================================================
# Q6 — forecasting revenue change (pure scan, the paper's Table 5 query).
# ===========================================================================

def plan_q6() -> QueryPlan:
    return QueryPlan(
        name="Q6", fact="lineitem",
        where=And((
            Pred("l_shipdate", ">=", D("1994-01-01")),
            Pred("l_shipdate", "<", D("1995-01-01")),
            Pred("l_discount", "between", (0.05, 0.07)),
            Pred("l_quantity", "<", 24),
        )),
        aggs=(Agg("sum", (Factor("l_extendedprice"), Factor("l_discount")), "revenue"),))


def run_q6(pl: Planner, year: int = 1994, disc=(0.05, 0.07), qty: int = 24) -> dict:
    bk, db = pl.bk, pl.db
    li = db.tables["lineitem"]
    expr = And((
        Pred("l_shipdate", ">=", D(f"{year}-01-01")),
        Pred("l_shipdate", "<", D(f"{year + 1}-01-01")),
        Pred("l_discount", "between", disc),
        Pred("l_quantity", "<", qty),
    ))
    mask = pl.where_mask(li, expr)
    rev = pl.aggregate(li, Agg("sum", (Factor("l_extendedprice"),
                                       Factor("l_discount")), "revenue"), mask)
    return {"revenue": _dec(bk, rev)}


def oracle_q6(db: Database, year: int = 1994, disc=(0.05, 0.07), qty: int = 24) -> dict:
    t = db.bk.t
    li = db.plain["lineitem"]
    lo, hi = int(round(disc[0] * 100)), int(round(disc[1] * 100))
    m = ((li["l_shipdate"] >= D(f"{year}-01-01"))
         & (li["l_shipdate"] < D(f"{year + 1}-01-01"))
         & (li["l_discount"] >= lo) & (li["l_discount"] <= hi)
         & (li["l_quantity"] < qty))
    return {"revenue": int((li["l_extendedprice"][m] * li["l_discount"][m]).sum()) % t}


# ===========================================================================
# Q4 — order priority checking (EXISTS semi-join).
# ===========================================================================

def plan_q4() -> QueryPlan:
    return QueryPlan(
        name="Q4", fact="orders",
        where=And((Pred("o_orderdate", ">=", D("1993-07-01")),
                   Pred("o_orderdate", "<", D("1993-10-01")))),
        hops=(JoinHop("orders", "l_orderkey", "lineitem"),),
        group_by="o_orderpriority", group_domain=5,
        aggs=(Agg("count", (), "order_count"),),
        correlated=True)


def run_q4(pl: Planner, d0: str = "1993-07-01", d1: str = "1993-10-01") -> dict:
    bk, db = pl.bk, pl.db
    orders, li = db.tables["orders"], db.tables["lineitem"]
    norders = orders.nrows
    assert norders <= bk.slots, "Q4 packs per-order counts into one ciphertext"
    # EXISTS(lineitem: commit < receipt, same order) as a per-order count.
    late = ops.pred_mask(bk, li, Pred("l_commitdate", "<", rhs_col="l_receiptdate"))
    late = ops.apply_validity(bk, late, li)
    counts = ops.join_aggregate(bk, li, "l_orderkey", norders, None, extra_mask=late)
    packed = ops.pack_scalars(bk, counts)
    # The packed counts sit ~eq_depth deep; the GT circuit needs ~eq_depth
    # more.  The planner injects one refresh here if the budget cannot
    # carry both (mask-injection tuning's "pay one bootstrap" branch).
    from .plan import lt_depth
    packed = bk.ensure_levels(packed, lt_depth(bk.t) + 2)
    exists = [cmp.gt_scalar(bk, packed, 0)]        # aligned with orders block 0
    date = pl.where_mask(orders, And((Pred("o_orderdate", ">=", D(d0)),
                                      Pred("o_orderdate", "<", D(d1)))))
    if pl.optimized:
        mask = ops.and_masks(bk, [exists, date])
    else:
        mask = ops.and_masks_seq(bk, [date, exists])
    out = {}
    pr_dict = _dict_of(db, "orders", "o_orderpriority")
    res = pl.group_aggregate(orders, "o_orderpriority",
                             [pr_dict[k] for k in sorted(pr_dict)],
                             (Agg("count", (), "order_count"),), mask)
    for name, pid in sorted(pr_dict.items()):
        out[name] = {"order_count": _dec(bk, res[pid]["order_count"])}
    return out


def oracle_q4(db: Database, d0: str = "1993-07-01", d1: str = "1993-10-01") -> dict:
    t = db.bk.t
    o, li = db.plain["orders"], db.plain["lineitem"]
    late_orders = set(li["l_orderkey"][li["l_commitdate"] < li["l_receiptdate"]].tolist())
    exists = np.isin(o["o_orderkey"], list(late_orders))
    date = (o["o_orderdate"] >= D(d0)) & (o["o_orderdate"] < D(d1))
    out = {}
    for name, pid in sorted(_dict_of(db, "orders", "o_orderpriority").items()):
        m = exists & date & (o["o_orderpriority"] == pid)
        out[name] = {"order_count": int(m.sum()) % t}
    return out


# ===========================================================================
# Q12 — shipping modes and order priority (join + CASE aggregation).
# ===========================================================================

def plan_q12() -> QueryPlan:
    hop = JoinHop("orders", "l_orderkey", "lineitem")
    return QueryPlan(
        name="Q12", fact="lineitem",
        where=And((Pred("l_shipmode", "in", ["MAIL", "SHIP"]),
                   Pred("l_commitdate", "<", rhs_col="l_receiptdate"),
                   Pred("l_shipdate", "<", rhs_col="l_commitdate"),
                   Pred("l_receiptdate", ">=", D("1994-01-01")),
                   Pred("l_receiptdate", "<", D("1995-01-01")))),
        hops=(hop,),
        group_by="l_shipmode", group_domain=2,
        # CASE aggregation: both counts partition on the translated
        # high-priority mask (the IN on l_shipmode doubles as the group
        # domain — the executor's group-pushdown rule).
        aggs=(Agg("count", (), "high_line_count", partition="high"),
              Agg("count", (), "low_line_count", partition="high",
                  negated=True)),
        aux_masks=(AuxMask("high", hop,
                           Pred("o_orderpriority", "in",
                                ["1-URGENT", "2-HIGH"])),))


def run_q12(pl: Planner, modes=("MAIL", "SHIP"), year: int = 1994) -> dict:
    bk, db = pl.bk, pl.db
    orders, li = db.tables["orders"], db.tables["lineitem"]
    pr_dict = _dict_of(db, "orders", "o_orderpriority")
    high_ids = [pr_dict[k] for k in ("1-URGENT", "2-HIGH") if k in pr_dict]
    # Priority mask computed on orders, pulled down to lineitem via the FK.
    high_orders = ops.pred_mask(bk, orders, Pred("o_orderpriority", "in",
                                                 [k for k in ("1-URGENT", "2-HIGH") if k in pr_dict]))
    assert orders.nblocks == 1
    where = pl.where_mask(li, And((
        Pred("l_commitdate", "<", rhs_col="l_receiptdate"),
        Pred("l_shipdate", "<", rhs_col="l_commitdate"),
        Pred("l_receiptdate", ">=", D(f"{year}-01-01")),
        Pred("l_receiptdate", "<", D(f"{year + 1}-01-01")))))
    where = ops.apply_validity(bk, where, li)
    # Unoptimized pipeline joins over the already-filtered fk column —
    # the Fig. 3(a) deep chain; the optimized plan joins the raw column.
    fk_ov = None if pl.optimized else ops.mask_columns(bk, li.col("l_orderkey").blocks, where)
    high_li = ops.translate_mask_down(bk, high_orders[0], li, "l_orderkey",
                                      orders.nrows, fk_override=fk_ov)
    sm_dict = _dict_of(db, "lineitem", "l_shipmode")
    out = {}
    for mode in modes:
        mmask = [cmp.eq_scalar(bk, ct, sm_dict[mode]) for ct in li.col("l_shipmode").blocks]
        if pl.optimized:
            base = ops.and_masks(bk, [mmask, where])
            hi = ops.and_masks(bk, [base, high_li])
        else:
            base = ops.and_masks_seq(bk, [where, mmask])
            hi = ops.and_masks_seq(bk, [base, high_li])
        lo = [bk.sub(b, h) for b, h in zip(base, hi)]     # low = base AND NOT high
        out[mode] = {"high_line_count": _dec(bk, ops.count(bk, hi)),
                     "low_line_count": _dec(bk, ops.count(bk, lo))}
    return out


def oracle_q12(db: Database, modes=("MAIL", "SHIP"), year: int = 1994) -> dict:
    t = db.bk.t
    o, li = db.plain["orders"], db.plain["lineitem"]
    pr_dict = _dict_of(db, "orders", "o_orderpriority")
    sm_dict = _dict_of(db, "lineitem", "l_shipmode")
    high_ids = {pr_dict[k] for k in ("1-URGENT", "2-HIGH") if k in pr_dict}
    order_high = np.isin(o["o_orderpriority"], list(high_ids))
    li_high = order_high[li["l_orderkey"] - 1]
    base = ((li["l_commitdate"] < li["l_receiptdate"])
            & (li["l_shipdate"] < li["l_commitdate"])
            & (li["l_receiptdate"] >= D(f"{year}-01-01"))
            & (li["l_receiptdate"] < D(f"{year + 1}-01-01")))
    out = {}
    for mode in modes:
        m = base & (li["l_shipmode"] == sm_dict[mode])
        out[mode] = {"high_line_count": int((m & li_high).sum()) % t,
                     "low_line_count": int((m & ~li_high).sum()) % t}
    return out


# ===========================================================================
# Q14 — promotion effect (2-way join + conditional aggregate).
# ===========================================================================

def plan_q14() -> QueryPlan:
    return QueryPlan(
        name="Q14", fact="lineitem",
        where=And((Pred("l_shipdate", ">=", D("1995-09-01")),
                   Pred("l_shipdate", "<", D("1995-10-01")))),
        hops=(JoinHop("part", "l_partkey", "lineitem",
                      parent_filter=Pred("p_type", "in", [])),),
        aggs=(Agg("sum", (Factor("l_extendedprice"), Factor("l_discount", -1, 100)),
                  "promo_revenue"),))


def run_q14(pl: Planner, d0: str = "1995-09-01", d1: str = "1995-10-01") -> dict:
    bk, db = pl.bk, pl.db
    part, li = db.tables["part"], db.tables["lineitem"]
    ty_dict = _dict_of(db, "part", "p_type")
    promo_ids = [v for k, v in ty_dict.items() if k.startswith("PROMO")]
    promo_part = ops.pred_mask(bk, part, Pred("p_type", "in",
                                              [k for k in ty_dict if k.startswith("PROMO")]))
    assert part.nblocks == 1
    date = pl.where_mask(li, And((Pred("l_shipdate", ">=", D(d0)),
                                  Pred("l_shipdate", "<", D(d1)))))
    date = ops.apply_validity(bk, date, li)
    fk_ov = None if pl.optimized else ops.mask_columns(bk, li.col("l_partkey").blocks, date)
    promo_li = ops.translate_mask_down(bk, promo_part[0], li, "l_partkey",
                                       part.nrows, fk_override=fk_ov)
    vals = ops.expr_blocks(bk, li, (Factor("l_extendedprice"), Factor("l_discount", -1, 100)))
    if pl.optimized:
        pm = ops.and_masks(bk, [promo_li, date])
    else:
        pm = ops.and_masks_seq(bk, [date, promo_li])
    return {"promo_revenue": _dec(bk, ops.masked_sum(bk, vals, pm)),
            "total_revenue": _dec(bk, ops.masked_sum(bk, vals, date))}


def oracle_q14(db: Database, d0: str = "1995-09-01", d1: str = "1995-10-01") -> dict:
    t = db.bk.t
    p, li = db.plain["part"], db.plain["lineitem"]
    ty_dict = _dict_of(db, "part", "p_type")
    promo_ids = {v for k, v in ty_dict.items() if k.startswith("PROMO")}
    part_promo = np.isin(p["p_type"], list(promo_ids))
    li_promo = part_promo[li["l_partkey"] - 1]
    date = (li["l_shipdate"] >= D(d0)) & (li["l_shipdate"] < D(d1))
    rev = li["l_extendedprice"] * (100 - li["l_discount"]) % t
    return {"promo_revenue": int(rev[date & li_promo].sum()) % t,
            "total_revenue": int(rev[date].sum()) % t}


# ===========================================================================
# Q19 — discounted revenue (three-branch disjunction of conjunctions).
# ===========================================================================

_Q19_BRANCHES = (
    dict(brand="Brand#12", containers=["SM BAG", "SM BOX", "SM CASE", "SM PACK"],
         qty=(1, 11), size=(1, 5)),
    dict(brand="Brand#23", containers=["MED BAG", "MED BOX", "MED JAR", "MED PACK"],
         qty=(10, 20), size=(1, 10)),
    dict(brand="Brand#34", containers=["LG BOX", "LG CASE", "LG PACK", "LG PKG"],
         qty=(20, 30), size=(1, 15)),
)


def plan_q19() -> QueryPlan:
    """The full three-branch disjunction as an executable IR tree: each
    branch's part-side conjunction sits under a Translated node (the
    l_partkey hop), ANDed with its lineitem quantity window; the common
    lineitem predicates join the disjunction at the top."""
    hop = JoinHop("part", "l_partkey", "lineitem")
    branches = []
    for br in _Q19_BRANCHES:
        part_expr = And((Pred("p_brand", "=", br["brand"]),
                         Pred("p_container", "in", br["containers"]),
                         Pred("p_size", "between", br["size"])))
        branches.append(And((Translated(hop, part_expr),
                             Pred("l_quantity", "between", br["qty"]))))
    return QueryPlan(
        name="Q19", fact="lineitem",
        where=And((Or(tuple(branches)),
                   Pred("l_shipmode", "in", ["AIR", "REG AIR"]),
                   Pred("l_shipinstruct", "=", "DELIVER IN PERSON"))),
        aggs=(Agg("sum", (Factor("l_extendedprice"), Factor("l_discount", -1, 100)),
                  "revenue"),))


def run_q19(pl: Planner) -> dict:
    bk, db = pl.bk, pl.db
    part, li = db.tables["part"], db.tables["lineitem"]
    assert part.nblocks == 1
    common = pl.where_mask(li, And((
        Pred("l_shipmode", "in", ["AIR", "REG AIR"]),
        Pred("l_shipinstruct", "=", "DELIVER IN PERSON"))))
    branch_masks = []
    for br in _Q19_BRANCHES:
        pmask = pl.where_mask(part, And((
            Pred("p_brand", "=", br["brand"]),
            Pred("p_container", "in", br["containers"]),
            Pred("p_size", "between", br["size"]))))
        down = ops.translate_mask_down(bk, pmask[0], li, "l_partkey", part.nrows)
        qmask = ops.pred_mask(bk, li, Pred("l_quantity", "between", br["qty"]))
        if pl.optimized:
            branch_masks.append(ops.and_masks(bk, [down, qmask]))
        else:
            branch_masks.append(ops.and_masks_seq(bk, [down, qmask]))
    disj = ops.or_masks(bk, branch_masks)
    full = (ops.and_masks(bk, [disj, common]) if pl.optimized
            else ops.and_masks_seq(bk, [disj, common]))
    full = ops.apply_validity(bk, full, li)
    vals = ops.expr_blocks(bk, li, (Factor("l_extendedprice"), Factor("l_discount", -1, 100)))
    return {"revenue": _dec(bk, ops.masked_sum(bk, vals, full))}


def oracle_q19(db: Database) -> dict:
    t = db.bk.t
    p, li = db.plain["part"], db.plain["lineitem"]
    br_d = _dict_of(db, "part", "p_brand")
    ct_d = _dict_of(db, "part", "p_container")
    sm_d = _dict_of(db, "lineitem", "l_shipmode")
    si_d = _dict_of(db, "lineitem", "l_shipinstruct")
    common = (np.isin(li["l_shipmode"], [sm_d.get("AIR", -1), sm_d.get("REG AIR", -1)])
              & (li["l_shipinstruct"] == si_d.get("DELIVER IN PERSON", -1)))
    disj = np.zeros(len(li["l_partkey"]), dtype=bool)
    for br in _Q19_BRANCHES:
        pm = ((p["p_brand"] == br_d.get(br["brand"], -1))
              & np.isin(p["p_container"], [ct_d.get(c, -1) for c in br["containers"]])
              & (p["p_size"] >= br["size"][0]) & (p["p_size"] <= br["size"][1]))
        lm = pm[li["l_partkey"] - 1] & (li["l_quantity"] >= br["qty"][0]) \
            & (li["l_quantity"] <= br["qty"][1])
        disj |= lm
    m = disj & common
    rev = li["l_extendedprice"] * (100 - li["l_discount"]) % t
    return {"revenue": int(rev[m].sum()) % t}


# ===========================================================================
# Q5 — local supplier volume (six-table join; paper runs it projected-only
# for the baselines).  Late injection: the region/nation membership bit is
# multiplied into the per-nation aggregate at the very end (R3, i* = m).
# ===========================================================================

def plan_q5() -> QueryPlan:
    return QueryPlan(
        name="Q5", fact="lineitem",
        where=And((Pred("o_orderdate", ">=", D("1994-01-01")),
                   Pred("o_orderdate", "<", D("1995-01-01")))),
        hops=(JoinHop("region", "n_regionkey", "nation",
                      parent_filter=Pred("r_name", "=", "ASIA")),
              JoinHop("nation", "s_nationkey", "supplier"),
              JoinHop("supplier", "l_suppkey", "lineitem"),
              JoinHop("orders", "l_orderkey", "lineitem")),
        group_by="n_name", group_domain=25,
        aggs=(Agg("sum", (Factor("l_extendedprice"), Factor("l_discount", -1, 100)),
                  "revenue"),))


def run_q5(pl: Planner, region: str = "ASIA", year: int = 1994) -> dict:
    bk, db = pl.bk, pl.db
    nation = db.tables["nation"]
    supplier, customer = db.tables["supplier"], db.tables["customer"]
    orders, li = db.tables["orders"], db.tables["lineitem"]
    r_dict = _dict_of(db, "region", "r_name")
    n_dict = _dict_of(db, "nation", "n_name")

    # Region membership, translated region -> nation (5 broadcasts).
    rmask = ops.pred_mask(bk, db.tables["region"], Pred("r_name", "=", region))
    asia_nation = ops.translate_mask_down(bk, rmask[0], nation, "n_regionkey", 5)

    # Date window on orders, translated down to lineitem rows.
    date = pl.where_mask(orders, And((Pred("o_orderdate", ">=", D(f"{year}-01-01")),
                                      Pred("o_orderdate", "<", D(f"{year + 1}-01-01")))))
    assert orders.nblocks == 1
    li_date = ops.translate_mask_down(bk, date[0], li, "l_orderkey", orders.nrows)

    # Customer-nation pulled to lineitem level through orders (two hops).
    o_custnat = ops.translate_values_down(
        bk, customer.col("c_nationkey").blocks[0], orders, "o_custkey", customer.nrows)
    li_custnat = ops.translate_values_down(bk, o_custnat[0], li, "l_orderkey", orders.nrows)
    # Supplier-nation pulled to lineitem level (one hop).
    li_suppnat = ops.translate_values_down(
        bk, supplier.col("s_nationkey").blocks[0], li, "l_suppkey", supplier.nrows)

    # The per-nation EQ below adds eq_depth on top of the translated value
    # columns: refresh them once here (planned) instead of per nation.
    from .plan import eq_depth
    need = eq_depth(bk.t) + 4
    li_custnat = [bk.ensure_levels(x, need) for x in li_custnat]
    li_suppnat = [bk.ensure_levels(x, need) for x in li_suppnat]

    vals = ops.expr_blocks(bk, li, (Factor("l_extendedprice"), Factor("l_discount", -1, 100)))
    out = {}
    for name, nid in sorted(n_dict.items()):
        supp_eq = [cmp.eq_scalar(bk, ct, nid) for ct in li_suppnat]
        cust_eq = [cmp.eq_scalar(bk, ct, nid) for ct in li_custnat]
        if pl.optimized:
            m = ops.and_masks(bk, [supp_eq, cust_eq, li_date])
        else:
            m = ops.and_masks_seq(bk, [li_date, supp_eq, cust_eq])
        m = ops.apply_validity(bk, m, li)
        # R3 late injection with the i* decision: inject the encrypted
        # "nation in region" bit on the aggregate (1 mul) when the budget
        # allows, else one level earlier on the mask (nblocks muls) —
        # extra multiplications are cheaper than a refresh (§4.3.2).
        bit = bk.broadcast_slot(asia_nation[0], nid - 1)
        rev = ops.masked_sum(bk, vals, m)
        if bk.levels_left(rev) >= 1:
            rev = bk.mul(rev, bit)
        else:
            m = [bk.mul(x, bit) for x in m]
            rev = ops.masked_sum(bk, vals, m)
        out[name] = {"revenue": _dec(bk, rev)}
    return out


def oracle_q5(db: Database, region: str = "ASIA", year: int = 1994) -> dict:
    t = db.bk.t
    r, n = db.plain["region"], db.plain["nation"]
    s, c = db.plain["supplier"], db.plain["customer"]
    o, li = db.plain["orders"], db.plain["lineitem"]
    r_dict = _dict_of(db, "region", "r_name")
    n_dict = _dict_of(db, "nation", "n_name")
    rid = r_dict[region]
    asia_nations = set((n["n_nationkey"][n["n_regionkey"] == rid]).tolist())
    date_ok = (o["o_orderdate"] >= D(f"{year}-01-01")) & (o["o_orderdate"] < D(f"{year + 1}-01-01"))
    li_date = date_ok[li["l_orderkey"] - 1]
    li_custnat = c["c_nationkey"][o["o_custkey"][li["l_orderkey"] - 1] - 1]
    li_suppnat = s["s_nationkey"][li["l_suppkey"] - 1]
    rev = li["l_extendedprice"] * (100 - li["l_discount"]) % t
    out = {}
    for name, nid in sorted(n_dict.items()):
        m = li_date & (li_custnat == nid) & (li_suppnat == nid)
        v = int(rev[m].sum()) % t if nid in asia_nations else 0
        out[name] = {"revenue": v}
    return out


# ===========================================================================
# Q8 — national market share.
# ===========================================================================

def plan_q8() -> QueryPlan:
    return QueryPlan(
        name="Q8", fact="lineitem",
        where=And((Pred("o_orderdate", ">=", D("1995-01-01")),
                   Pred("o_orderdate", "<=", D("1996-12-31")))),
        hops=(JoinHop("region", "n_regionkey", "nation",
                      parent_filter=Pred("r_name", "=", "AMERICA")),
              JoinHop("nation", "c_nationkey", "customer"),
              JoinHop("customer", "o_custkey", "orders"),
              JoinHop("orders", "l_orderkey", "lineitem"),
              JoinHop("part", "l_partkey", "lineitem"),
              JoinHop("supplier", "l_suppkey", "lineitem")),
        group_by="o_year", group_domain=2,
        aggs=(Agg("sum", (Factor("l_extendedprice"), Factor("l_discount", -1, 100)),
                  "mkt_share"),))


def run_q8(pl: Planner, region: str = "AMERICA", nation: str = "BRAZIL",
           ptype: str = "ECONOMY ANODIZED") -> dict:
    bk, db = pl.bk, pl.db
    nat, cust = db.tables["nation"], db.tables["customer"]
    supp, part = db.tables["supplier"], db.tables["part"]
    orders, li = db.tables["orders"], db.tables["lineitem"]
    n_dict = _dict_of(db, "nation", "n_name")

    # region -> nation -> customer membership chain (shallow: each hop is an
    # EQ on a fresh key column x broadcast bit).
    rmask = ops.pred_mask(bk, db.tables["region"], Pred("r_name", "=", region))
    nmask = ops.translate_mask_down(bk, rmask[0], nat, "n_regionkey", 5)
    cmask = ops.translate_mask_down(bk, nmask[0], cust, "c_nationkey", 25)
    omask = ops.translate_mask_down(bk, cmask[0], orders, "o_custkey", cust.nrows)

    vals = ops.expr_blocks(bk, li, (Factor("l_extendedprice"), Factor("l_discount", -1, 100)))
    # part-type mask down to lineitem (stage 1 of the classical pipeline).
    pmask = ops.pred_mask(bk, part, Pred("p_type", "=", ptype))
    li_part = ops.translate_mask_down(bk, pmask[0], li, "l_partkey", part.nrows)
    # supplier-is-<nation> mask at supplier level, then down to lineitem.
    # Unoptimized: this join scans the fk already filtered by stage 1.
    nid = n_dict.get(nation, len(n_dict) + 1)
    smask = [cmp.eq_scalar(bk, supp.col("s_nationkey").blocks[0], nid)]
    fk_s = None if pl.optimized else ops.mask_columns(bk, li.col("l_suppkey").blocks, li_part)
    li_braz = ops.translate_mask_down(bk, smask[0], li, "l_suppkey", supp.nrows,
                                      fk_override=fk_s)

    out = {}
    for yr in (1995, 1996):
        dmask = pl.where_mask(orders, And((Pred("o_orderdate", ">=", D(f"{yr}-01-01")),
                                           Pred("o_orderdate", "<=", D(f"{yr}-12-31")))))
        oy = ([bk.mul(a, b) for a, b in zip(omask, dmask)] if pl.optimized
              else ops.and_masks_seq(bk, [omask, dmask]))
        fk_o = None if pl.optimized else ops.mask_columns(bk, li.col("l_orderkey").blocks, li_part)
        li_amer = ops.translate_mask_down(bk, oy[0], li, "l_orderkey", orders.nrows,
                                          fk_override=fk_o)
        if pl.optimized:
            base = ops.and_masks(bk, [li_amer, li_part])
            braz = ops.and_masks(bk, [base, li_braz])
        else:
            base = ops.and_masks_seq(bk, [li_amer, li_part])
            braz = ops.and_masks_seq(bk, [base, li_braz])
        base = ops.apply_validity(bk, base, li)
        braz = ops.apply_validity(bk, braz, li)
        out[yr] = {"nation_volume": _dec(bk, ops.masked_sum(bk, vals, braz)),
                   "total_volume": _dec(bk, ops.masked_sum(bk, vals, base))}
    return out


def oracle_q8(db: Database, region: str = "AMERICA", nation: str = "BRAZIL",
              ptype: str = "ECONOMY ANODIZED") -> dict:
    t = db.bk.t
    n, c = db.plain["nation"], db.plain["customer"]
    s, p = db.plain["supplier"], db.plain["part"]
    o, li = db.plain["orders"], db.plain["lineitem"]
    rid = _dict_of(db, "region", "r_name").get(region, -1)
    nid = _dict_of(db, "nation", "n_name").get(nation, -1)
    tid = _dict_of(db, "part", "p_type").get(ptype, -1)
    amer_nat = set(n["n_nationkey"][n["n_regionkey"] == rid].tolist())
    cust_amer = np.isin(c["c_nationkey"], list(amer_nat))
    ord_amer = cust_amer[o["o_custkey"] - 1]
    li_amer = ord_amer[li["l_orderkey"] - 1]
    li_part = (p["p_type"] == tid)[li["l_partkey"] - 1]
    li_braz = (s["s_nationkey"] == nid)[li["l_suppkey"] - 1]
    rev = li["l_extendedprice"] * (100 - li["l_discount"]) % t
    odate = o["o_orderdate"][li["l_orderkey"] - 1]
    out = {}
    for yr in (1995, 1996):
        dm = (odate >= D(f"{yr}-01-01")) & (odate <= D(f"{yr}-12-31"))
        base = li_amer & li_part & dm
        out[yr] = {"nation_volume": int(rev[base & li_braz].sum()) % t,
                   "total_volume": int(rev[base].sum()) % t}
    return out


# ===========================================================================
# Q17 — small-quantity-order revenue (correlated subquery on per-part AVG).
# ===========================================================================

def plan_q17() -> QueryPlan:
    return QueryPlan(
        name="Q17", fact="lineitem",
        where=And((Pred("p_brand", "=", "Brand#23"),
                   Pred("p_container", "=", "MED BOX"))),
        hops=(JoinHop("part", "l_partkey", "lineitem"),),
        aggs=(Agg("sum", (Factor("l_extendedprice"),), "avg_yearly_x7"),),
        correlated=True)


def run_q17(pl: Planner, brand: str = "Brand#23", container: str = "MED BOX") -> dict:
    bk, db = pl.bk, pl.db
    part, li = db.tables["part"], db.tables["lineitem"]
    npart = part.nrows
    assert part.nblocks == 1 and npart <= bk.slots

    # Per-part SUM(l_quantity) and COUNT (the paper's AVG-as-pair rewrite).
    ones = None
    qty = li.col("l_quantity").blocks
    valid = li.validity(li.nblocks - 1)
    sums = ops.join_aggregate(bk, li, "l_partkey", npart, qty)
    cnts = ops.join_aggregate(bk, li, "l_partkey", npart, None)
    packed_sum = ops.pack_scalars(bk, sums)
    packed_cnt = ops.pack_scalars(bk, cnts)
    # Pull per-part aggregates down to lineitem rows.
    li_sum = ops.translate_values_down(bk, packed_sum, li, "l_partkey", npart)
    li_cnt = ops.translate_values_down(bk, packed_cnt, li, "l_partkey", npart)
    # qty < 0.2 * sum/cnt  ==  5*qty*cnt < sum  (query rewriting, §4.2.2).
    from .plan import lt_depth
    lhs = [bk.mul_scalar(bk.mul(q, c), 5) for q, c in zip(qty, li_cnt)]
    # Planned refresh: the LT operands carry ~eq_depth+2 levels already and
    # the comparison needs ~eq_depth+1 more — one refresh per block beats
    # the mid-circuit thrash (the i* cost model's infeasible branch).
    need = lt_depth(bk.t) + 1
    lhs = [bk.ensure_levels(x, need) for x in lhs]
    li_sum = [bk.ensure_levels(x, need) for x in li_sum]
    small = [ops._col_cmp(bk, a, "<", b) for a, b in zip(lhs, li_sum)]

    pmask = pl.where_mask(part, And((Pred("p_brand", "=", brand),
                                     Pred("p_container", "=", container))))
    li_pm = ops.translate_mask_down(bk, pmask[0], li, "l_partkey", npart)
    full = (ops.and_masks(bk, [small, li_pm]) if pl.optimized
            else ops.and_masks_seq(bk, [li_pm, small]))
    full = ops.apply_validity(bk, full, li)
    total = ops.masked_sum(bk, li.col("l_extendedprice").blocks, full)
    return {"avg_yearly_x7": _dec(bk, total)}


def oracle_q17(db: Database, brand: str = "Brand#23", container: str = "MED BOX") -> dict:
    t = db.bk.t
    p, li = db.plain["part"], db.plain["lineitem"]
    bid = _dict_of(db, "part", "p_brand").get(brand, -1)
    cid = _dict_of(db, "part", "p_container").get(container, -1)
    pm = (p["p_brand"] == bid) & (p["p_container"] == cid)
    li_pm = pm[li["l_partkey"] - 1]
    nparts = len(p["p_partkey"])
    sums = np.zeros(nparts + 1, dtype=np.int64)
    cnts = np.zeros(nparts + 1, dtype=np.int64)
    np.add.at(sums, li["l_partkey"], li["l_quantity"])
    np.add.at(cnts, li["l_partkey"], 1)
    small = 5 * li["l_quantity"] * cnts[li["l_partkey"]] < sums[li["l_partkey"]]
    m = small & li_pm
    return {"avg_yearly_x7": int(li["l_extendedprice"][m].sum()) % t}


# Queries whose plans lower fully to the physical operator DAG:
# run_via_plan(planner, plan_qN()) must equal run_qN(planner) exactly.
PLAN_EXECUTABLE = ("Q1", "Q6", "Q12", "Q19")

QUERIES = {
    "Q1": (plan_q1, run_q1, oracle_q1),
    "Q4": (plan_q4, run_q4, oracle_q4),
    "Q5": (plan_q5, run_q5, oracle_q5),
    "Q6": (plan_q6, run_q6, oracle_q6),
    "Q8": (plan_q8, run_q8, oracle_q8),
    "Q12": (plan_q12, run_q12, oracle_q12),
    "Q14": (plan_q14, run_q14, oracle_q14),
    "Q17": (plan_q17, run_q17, oracle_q17),
    "Q19": (plan_q19, run_q19, oracle_q19),
}
