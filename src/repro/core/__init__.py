"""repro.core — RNS-BFV leveled homomorphic encryption in JAX.

The paper's primary contribution (word-level LHE query execution) builds
on this package: parameter sets, negacyclic NTT, the BFV scheme with HPS
RNS multiplication, batch encoding, noise accounting, and the arithmetic
comparison circuits (Fermat equality, BSGS range).

The HE arithmetic needs exact 60-bit integer products, so x64 must be on
before any JAX array is created. Importing repro.core flips it.
"""
import jax

jax.config.update("jax_enable_x64", True)

from .params import HEParams, make_params, paper_params, small_params, test_params  # noqa: E402,F401
