"""Negacyclic number-theoretic transform — pure-jnp reference path.

Layout convention: polynomials are (k, n) int64 arrays — k RNS limbs of an
n-coefficient polynomial, coefficients in [0, q_i). The forward transform
uses Cooley-Tukey butterflies with premultiplied psi powers (Longa-Naehrig)
and produces the evaluation vector in bit-reversed order; the inverse uses
Gentleman-Sande butterflies and consumes that order, so pointwise products
round-trip without explicit bit-reversal passes.

This module is (a) the execution path on CPU and (b) the oracle for the
Pallas kernel in kernels/ntt. Products are <= (2^30-1)^2 < 2^63: exact in
int64.
"""
from __future__ import annotations

import jax.numpy as jnp


def ntt_ref(a, psi_rev, q):
    """Forward negacyclic NTT. a: (k, n); psi_rev: (k, n); q: (k,)."""
    k, n = a.shape
    qc = q[:, None, None]
    log_n = n.bit_length() - 1
    for s in range(log_n):
        m = 1 << s
        t_len = n >> (s + 1)
        a = a.reshape(k, m, 2, t_len)
        S = psi_rev[:, m : 2 * m]  # (k, m)
        U = a[:, :, 0, :]
        V = (a[:, :, 1, :] * S[:, :, None]) % qc
        a = jnp.stack([(U + V) % qc, (U - V) % qc], axis=2)
    return a.reshape(k, n)


def intt_ref(a, ipsi_rev, n_inv, q):
    """Inverse negacyclic NTT (consumes bit-reversed evaluation order)."""
    k, n = a.shape
    qc = q[:, None, None]
    log_n = n.bit_length() - 1
    for s in range(log_n):
        t_len = 1 << s
        h = n >> (s + 1)
        a = a.reshape(k, h, 2, t_len)
        S = ipsi_rev[:, h : 2 * h]  # (k, h)
        U = a[:, :, 0, :]
        V = a[:, :, 1, :]
        a = jnp.stack([(U + V) % qc, ((U - V) * S[:, :, None]) % qc], axis=2)
    a = a.reshape(k, n)
    return (a * n_inv[:, None]) % q[:, None]


def pointwise_mul(a, b, q):
    """Hadamard product of evaluation vectors. (k, n) x (k, n) -> (k, n)."""
    return (a * b) % q[:, None]


def polymul_ref(a, b, tables):
    """Full negacyclic polynomial product via NTT (test helper)."""
    fa = ntt_ref(a, tables.psi_rev, tables.q)
    fb = ntt_ref(b, tables.psi_rev, tables.q)
    return intt_ref(pointwise_mul(fa, fb, tables.q), tables.ipsi_rev, tables.n_inv, tables.q)


def negacyclic_naive(a, b, q):
    """O(n^2) schoolbook negacyclic product — independent oracle for tests.

    a, b: (n,) python/numpy int arrays (single limb); returns (n,) mod q.
    """
    import numpy as np

    n = len(a)
    out = np.zeros(n, dtype=object)
    for i in range(n):
        ai = int(a[i])
        if ai == 0:
            continue
        for j in range(n):
            e = i + j
            v = ai * int(b[j])
            if e < n:
                out[e] += v
            else:
                out[e - n] -= v
    return np.array([int(x) % q for x in out], dtype=np.int64)
