"""Pure-Python number theory used at parameter-construction time.

Everything here runs once per parameter set (host side, Python ints), so
clarity beats speed. All runtime polynomial arithmetic lives in ntt.py /
kernels/ and operates on fixed-size JAX arrays.
"""
from __future__ import annotations

from functools import lru_cache


def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3e24 (covers all our primes)."""
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def modinv(a: int, m: int) -> int:
    return pow(a % m, -1, m)


def find_ntt_primes(n: int, bits: int, count: int, avoid: tuple[int, ...] = ()) -> list[int]:
    """`count` distinct primes q ≡ 1 (mod 2n), q < 2**bits, descending from 2**bits.

    q ≡ 1 (mod 2n) guarantees a primitive 2n-th root of unity mod q, which
    the negacyclic NTT needs.
    """
    step = 2 * n
    q = (1 << bits) - ((1 << bits) - 1) % step  # largest q < 2^bits with q ≡ 1 (mod 2n)
    out: list[int] = []
    while len(out) < count:
        if q <= step:
            raise ValueError(f"ran out of {bits}-bit NTT primes for n={n}")
        if is_prime(q) and q not in avoid and q not in out:
            out.append(q)
        q -= step
    return out


def _factorize(n: int) -> list[int]:
    fs, d = [], 2
    while d * d <= n:
        if n % d == 0:
            fs.append(d)
            while n % d == 0:
                n //= d
        d += 1
    if n > 1:
        fs.append(n)
    return fs


@lru_cache(maxsize=None)
def primitive_root(q: int) -> int:
    """Smallest generator of (Z/q)* for prime q."""
    factors = _factorize(q - 1)
    for g in range(2, q):
        if all(pow(g, (q - 1) // f, q) != 1 for f in factors):
            return g
    raise ValueError(f"no generator found for {q}")


def root_of_unity(order: int, q: int) -> int:
    """A primitive `order`-th root of unity mod prime q (order | q-1)."""
    if (q - 1) % order != 0:
        raise ValueError(f"{order} does not divide {q}-1")
    g = primitive_root(q)
    w = pow(g, (q - 1) // order, q)
    # Certify primitivity: w^(order/p) != 1 for every prime p | order.
    for p in _factorize(order):
        if pow(w, order // p, q) == 1:
            raise AssertionError("non-primitive root")
    return w


def bit_reverse(x: int, bits: int) -> int:
    r = 0
    for _ in range(bits):
        r = (r << 1) | (x & 1)
        x >>= 1
    return r


def crt_reconstruct(residues: list[int], moduli: list[int]) -> int:
    """Exact CRT: the unique X in [0, prod(moduli)) with X ≡ r_i (mod m_i)."""
    Q = 1
    for m in moduli:
        Q *= m
    X = 0
    for r, m in zip(residues, moduli):
        Qi = Q // m
        X = (X + int(r) * Qi * modinv(Qi, m)) % Q
    return X


def centered(x: int, q: int) -> int:
    """Centered representative in (-q/2, q/2]."""
    x %= q
    return x - q if x > q // 2 else x
