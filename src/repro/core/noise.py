"""Analytic invariant-noise accounting.

We track, per ciphertext, log2 of the *invariant noise* |v|, where
decrypting computes (t/Q)(c0 + c1 s) = m + v + t*K and succeeds iff
|v| < 1/2. `budget_bits = -log2(2|v|)` matches SEAL's
invariant_noise_budget. The planner (engine/planner.py) consumes the same
model; tests cross-check these bounds against exact noise measured with
the secret key (core/bfv.py:noise_budget_exact).

Bounds follow the standard BFV worst-case analysis (Fan-Vercauteren /
SEAL manual), specialized to our RNS layout:
  fresh:      |v| <= (t/Q) * B * (2 n W + W + 1),  W = Hamming-ish bound 1
              for ternary u/s, B = ceil(6 sigma) error bound
  add:        v = v1 + v2
  mul:        |v| <~ (v1 + v2) * t * n + small cross terms
  keyswitch:  additive (t/Q) * n * k * q_max * B / 2  (per-limb digits)
  mul_plain:  |v| *= n * ||m||_inf  (<= n * t/2 for arbitrary masks)
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .params import HEParams


@dataclasses.dataclass(frozen=True)
class NoiseProfile:
    """Lightweight stand-in for HEParams: just what NoiseModel reads.

    Used by the mock backend to run *paper-scale* parameter accounting
    (n=32768, 30 limbs) without building NTT tables.
    """

    n: int
    t: int
    k: int
    qbits: int = 30
    err_std: float = 3.2

    @property
    def logQ(self) -> float:
        return self.k * (self.qbits - 2e-5)  # primes sit just below 2^qbits

    @property
    def q_max(self) -> int:
        return (1 << self.qbits) - 1

    @property
    def slots(self) -> int:
        return self.n

    @property
    def ct_bytes(self) -> int:
        return 2 * self.k * self.n * ((self.qbits + 7) // 8)

    def expansion_ratio(self, raw_bits: int = 16) -> float:
        return self.ct_bytes / (self.n * raw_bits / 8)


def paper_profile() -> NoiseProfile:
    """The paper's SEAL set: n=32768, log Q = 881, t = 65537."""
    return NoiseProfile(n=32768, t=65537, k=30)


@dataclasses.dataclass
class NoiseModel:
    params: "HEParams | NoiseProfile"

    def __post_init__(self) -> None:
        p = self.params
        self.logQ = p.logQ
        self.log_t = math.log2(p.t)
        self.log_n = math.log2(p.n)
        self.log_B = math.log2(math.ceil(6 * p.err_std))

    # All values are log2|v| of invariant noise.
    def fresh(self) -> float:
        p = self.params
        return self.log_t - self.logQ + self.log_B + math.log2(2 * p.n + p.n + 1)

    @staticmethod
    def _logadd(v1, v2):
        """log2(2^v1 + 2^v2), stable — |u + w| <= |u| + |w|.  Sequential
        sums of k equal-noise terms grow by log2(k), not by k bits.

        Accepts floats or numpy arrays (per-block noise vectors); scalar
        inputs take the original scalar path bit-for-bit.
        """
        if np.ndim(v1) == 0 and np.ndim(v2) == 0:
            hi, lo = (v1, v2) if v1 >= v2 else (v2, v1)
            d = lo - hi
            if d < -50:
                return hi
            return hi + math.log2(1.0 + 2.0 ** d)
        hi = np.maximum(v1, v2)
        d = np.minimum(v1, v2) - hi
        return np.where(d < -50, hi, hi + np.log2(1.0 + 2.0 ** np.maximum(d, -60.0)))

    def add(self, v1, v2):
        return self._logadd(v1, v2)

    def add_many(self, vs):
        shift = math.log2(max(len(vs), 1))
        if all(np.ndim(v) == 0 for v in vs):
            return max(vs) + shift
        hi = vs[0]
        for v in vs[1:]:
            hi = np.maximum(hi, v)
        return hi + shift

    def mul(self, v1, v2):
        # (|v1|+|v2|) * t * n  + tensor rounding term (t/Q-scale, negligible
        # until the very bottom of the budget).
        grow = self.log_t + self.log_n + 1.0
        base = self._logadd(v1, v2) + grow
        floor_term = self.log_t + self.log_n - self.logQ + 2.0
        if np.ndim(base) == 0:
            return max(base, floor_term)
        return np.maximum(base, floor_term)

    def levels_left(self, v) -> int:
        """Sequential ct-ct multiplications this ciphertext still supports.

        For a per-block noise vector this is the *worst* lane's count."""
        if np.ndim(v):
            v = float(np.max(v))
        d = 0
        while True:
            v2 = self.keyswitch(self.mul(v, v))
            if self.budget(v2) <= 0:
                return d
            v, d = v2, d + 1

    def keyswitch_addend(self) -> float:
        p = self.params
        q_max = max(p.Q.primes) if hasattr(p, "Q") else p.q_max
        return self.log_t - self.logQ + self.log_n + math.log2(p.k) + math.log2(q_max) + self.log_B - 1.0

    def keyswitch(self, v):
        addend = self.keyswitch_addend()
        if np.ndim(v) == 0:
            return max(v, addend) + 1.0
        return np.maximum(v, addend) + 1.0

    def rotate(self, v):
        return self.keyswitch(v)

    def mul_plain(self, v, plain_inf_norm: float | None = None):
        norm = plain_inf_norm if plain_inf_norm is not None else self.params.t / 2
        return v + self.log_n + math.log2(max(norm, 1.0))

    def mul_scalar(self, v, c: int):
        """Multiply by a constant polynomial (degree 0): |v| grows by |c| only,
        no n factor — the reason BSGS coefficient multiplies are cheap."""
        t = self.params.t
        cc = abs(c % t if (c % t) <= t // 2 else (c % t) - t)
        return v + math.log2(max(cc, 1))

    def budget(self, v):
        """Remaining invariant-noise budget in bits (<0 means failure).
        Elementwise over per-block noise vectors."""
        return -(v + 1.0)

    def min_budget(self, v) -> float:
        """Worst-lane remaining budget in bits as a scalar — the decrypt
        -boundary headroom both the executing backends and the static
        verifier report."""
        return float(np.min(self.budget(v)))

    # --- planner-facing depth model (paper Table 3) ---
    def max_depth(self) -> int:
        """Supported sequential ct-ct multiplication depth from fresh."""
        v = self.fresh()
        d = 0
        while True:
            v2 = self.mul(v, v)
            if self.budget(v2) <= 0:
                return d
            v = v2
            d += 1

    def eq_depth(self) -> int:
        return math.ceil(math.log2(self.params.t - 1))

    def lt_depth(self) -> int:
        return self.eq_depth() + 1  # BSGS: baby chain + giant chain ~ log(p-1), +1 slack

    def agg_depth(self) -> float:
        return math.log2(self.params.n) / self.params.t

    def join_depth(self) -> int:
        return self.eq_depth() + 1


class UnderReportingNoiseModel:
    """Delegating NoiseModel wrapper that *under-reports* ct-ct multiply
    noise growth — the fault-injection stand-in for a mis-calibrated
    model (runtime/faults.py, DESIGN.md §9 'overflow').

    On each tampered `mul` the reported noise is `extra_bits` lower than
    the inner model's answer, and the shortfall accumulates in
    `hidden_bits`.  The engine's refresh policy then under-provisions:
    ciphertexts reach decrypt with less real headroom than their
    tracked noise claims.  The decrypt-boundary guard
    (`faults.check_decrypt`) subtracts `hidden_bits` to detect exactly
    this — the injected equivalent of a real backend's noise exceeding
    the analytic bound.

    `skip` passes through the first N mul calls untouched (placing the
    fault mid-plan); `take()` is consulted per call so the armed
    FaultPlan can bound how many tampered muls fire across retries.
    Every other model method (budget, keyswitch, levels_left, ...)
    delegates verbatim, so planning and refresh sizing stay coherent
    with the lie — the scenario is a consistent model bias, not a
    one-off glitch the accounting would immediately expose.
    """

    def __init__(self, inner: NoiseModel, extra_bits: float,
                 skip: int = 0, take=None):
        self.inner = inner
        self.extra_bits = float(extra_bits)
        self._skip = int(skip)
        self._take = take if take is not None else (lambda: True)
        self.hidden_bits = 0.0

    def mul(self, v1, v2):
        out = self.inner.mul(v1, v2)
        if self._skip > 0:
            self._skip -= 1
            return out
        if not self._take():
            return out
        self.hidden_bits += self.extra_bits
        return out - self.extra_bits

    def __getattr__(self, name):
        return getattr(self.inner, name)
