"""Arithmetic comparison circuits for word-level BFV (paper §2.1.7, §4.3.1).

Everything here is written against a duck-typed backend `ops` (see
engine/backend.py) exposing add/sub/mul/mul_scalar/add_scalar/
sub_from_scalar and the plaintext modulus `ops.t`.  The identical circuit
therefore runs on real RNS-BFV ciphertexts (tests, small benches) and on
the mock Z_t backend (full-scale TPC-H benches) without drift.

Equality  — Fermat's little theorem (paper Eq. 3):
    EQ(x, y) = 1 - (x-y)^(p-1),   depth = ceil(log2(p-1))  via square chain.

Less-than — the paper's Eq. 4 is a sum over the whole negative half-range;
evaluated literally it costs (p-1)/2 equality circuits.  Following the
optimization the paper adopts from Iliashenko-Zucca [38], we instead
interpolate once:

    sgn(z)  = sum_{j} s_j z^(2j+1)      (odd polynomial, degree p-2)
    LT(x,y) = ( z^(p-1) - sgn(z) ) / 2,     z = x - y

since z^(p-1) is 1 iff z != 0 and sgn is +-1 on the positive/negative
halves.  The odd interpolant needs only (p-1)/2 coefficients

    s_k = -2 * sum_{a=1..(p-1)/2} a^(p-1-k)  (mod p),  k odd,

and is evaluated in the variable w = z^2 with a depth-balanced
divide-and-conquer Paterson-Stockmeyer scheme: ~2*sqrt(p) ciphertext
multiplications at multiplicative depth ceil(log2(p-1)) + 2 — matching the
paper's Table 3 ("Equality: log(p-1); Join: log(p-1)+1") up to the BSGS
slack noted in §5.3 ("inequality checks ... lookup table accesses (BSGS)").
"""
from __future__ import annotations

import os
from functools import lru_cache

import numpy as np

# ---------------------------------------------------------------------------
# Interpolation coefficients (host-side precompute, cached on disk).
# ---------------------------------------------------------------------------

_CACHE_DIR = os.path.join(os.path.dirname(__file__), "_coeff_cache")


def _modpow_vec(base: np.ndarray, e: int, p: int) -> np.ndarray:
    """Vectorized modular exponentiation; products < p^2 < 2^34, exact int64."""
    out = np.ones_like(base)
    b = base % p
    while e:
        if e & 1:
            out = out * b % p
        b = b * b % p
        e >>= 1
    return out


@lru_cache(maxsize=None)
def sgn_odd_coeffs(p: int) -> np.ndarray:
    """s[j] = coefficient of z^(2j+1) in the interpolant of sgn over Z_p.

    Returned as int64 array of length (p-1)//2 (degree p-2 polynomial).
    Cached to disk: the p=65537 table costs ~2^30 modmuls to build.
    """
    path = os.path.join(_CACHE_DIR, f"sgn_{p}.npy")
    if os.path.exists(path):
        return np.load(path)
    half = (p - 1) // 2
    a = np.arange(1, half + 1, dtype=np.int64)
    # k = 2j+1:  s_j = -2 * sum_a a^(p-1-k).  Iterate v_a = a^(p-1-k)
    # starting at k=1 (v = a^(p-2)) and multiply by a^-2 each step.
    v = _modpow_vec(a, p - 2, p)
    ainv2 = _modpow_vec(a, p - 3, p)  # a^(p-3) = a^-2
    s = np.zeros(half, dtype=np.int64)
    for j in range(half):
        s[j] = (-2 * int(v.sum() % p)) % p
        if j + 1 < half:
            v = v * ainv2 % p
    os.makedirs(_CACHE_DIR, exist_ok=True)
    np.save(path, s)
    return s


@lru_cache(maxsize=None)
def indicator_coeffs(p: int, lo: int, hi: int) -> np.ndarray:
    """Dense interpolant f with f(a) = 1 for a in [lo, hi] (centered reps),
    0 elsewhere.  f_0 = g(0); f_k = -sum_{a != 0} g(a) a^(p-1-k).
    Used for small-p tests and as an oracle for the sgn decomposition."""
    members = [a % p for a in range(lo, hi + 1)]
    g = np.zeros(p, dtype=np.int64)
    g[members] = 1
    coeffs = np.zeros(p, dtype=np.int64)
    coeffs[0] = g[0]
    a = np.arange(1, p, dtype=np.int64)
    ga = g[1:]
    v = _modpow_vec(a, p - 2, p)  # a^(p-1-k) at k=1
    ainv = _modpow_vec(a, p - 2, p)
    for k in range(1, p):
        coeffs[k] = (-int((ga * v % p).sum() % p)) % p
        if k + 1 < p:
            v = v * ainv % p
    return coeffs


# ---------------------------------------------------------------------------
# Circuits.
# ---------------------------------------------------------------------------

def _is_pow2(x: int) -> bool:
    return x > 0 and x & (x - 1) == 0


def pow_ct(ops, x, e: int):
    """x^e by square-and-multiply (depth ceil(log2 e) for e a power of two)."""
    assert e >= 1
    acc = None
    base = x
    while e:
        if e & 1:
            acc = base if acc is None else ops.mul(acc, base)
        e >>= 1
        if e:
            base = ops.mul(base, base)
    return acc


def eq_zero(ops, z):
    """EQ(z, 0) = 1 - z^(p-1); depth ceil(log2(p-1)) (16 for t=65537)."""
    if hasattr(ops, "op_log"):
        ops.op_log["eq"] += 1
    return ops.sub_from_scalar(1, pow_ct(ops, z, ops.t - 1))


def eq_ct(ops, x, y):
    """Paper Eq. 3: EQ(x, y) = 1 - (x-y)^(p-1)."""
    return eq_zero(ops, ops.sub(x, y))


def eq_scalar(ops, x, c: int):
    return eq_zero(ops, ops.sub_scalar(x, c))


class _PSEvaluator:
    """Depth-balanced Paterson-Stockmeyer over w-powers of one ciphertext.

    Baby powers w^1..w^(B-1) built by balanced products (depth log2 B);
    giant powers w^(B*2^j) from the squaring chain; a polynomial of degree
    d is split recursively at power-of-two multiples of B, costing one
    ct-ct mul per split and depth log2(d/B) above the baby level.
    """

    def __init__(self, ops, w, max_degree: int):
        self.ops = ops
        self.w = w
        b = 1
        while b * b < max_degree + 1:
            b *= 2
        self.B = b
        self._baby = {1: w}   # w^i
        self._pow2 = {1: w}   # w^(2^j) keyed by 2^j
        for i in range(2, b):
            self._baby[i] = ops.mul(self.baby(i // 2), self.baby(i - i // 2))
        m = 2
        while m <= max_degree + 1:
            prev = self._pow2[m // 2]
            self._pow2[m] = self._baby[m] if m in self._baby else ops.mul(prev, prev)
            m *= 2

    def baby(self, i: int):
        return self._baby[i]

    def pow2(self, m: int):
        return self._pow2[m]

    def eval(self, coeffs: np.ndarray):
        """sum_i coeffs[i] * w^i as a ciphertext (None if identically 0)."""
        return self._eval(np.asarray(coeffs, dtype=np.int64))

    def _eval(self, c: np.ndarray):
        ops, p = self.ops, self.ops.t
        n = len(c)
        if n <= self.B:
            acc = None
            if any(int(x) % p for x in c[1:]):
                cts = [self.baby(i) for i in range(1, n)]
                acc = ops.dot_plain(cts, c[1:])
            c0 = int(c[0]) % p
            if c0:
                if acc is None:
                    raise ValueError("constant-only polynomial: fold into caller")
                acc = ops.add_scalar(acc, c0)
            return acc
        m = self.B
        while m * 2 < n:
            m *= 2
        lo = self._eval(c[:m])
        hi = self._eval(c[m:])
        if hi is None:
            return lo
        hi = ops.mul(hi, self.pow2(m))
        return hi if lo is None else ops.add(lo, hi)


def lt_zero(ops, z):
    """LT(z, 0): encrypted 1 iff z is in the negative half range, else 0."""
    if hasattr(ops, "op_log"):
        ops.op_log["cmp"] += 1
    p = ops.t
    assert _is_pow2(p - 1), "sgn decomposition assumes a Fermat prime t"
    s = sgn_odd_coeffs(p)                      # h(w): sgn(z) = z * h(z^2)
    w = ops.mul(z, z)
    ps = _PSEvaluator(ops, w, len(s) - 1)
    h = ps.eval(s)
    sgn = ops.mul(z, h)
    ez = ps.pow2((p - 1) // 2)                 # w^((p-1)/2) = z^(p-1)
    inv2 = (p + 1) // 2
    return ops.mul_scalar(ops.sub(ez, sgn), inv2)


def lt_ct(ops, x, y):
    """LT(x, y) (paper Eq. 4, evaluated via the interpolant)."""
    return lt_zero(ops, ops.sub(x, y))


def lt_scalar(ops, x, c: int):
    return lt_zero(ops, ops.sub_scalar(x, c))


def gt_scalar(ops, x, c: int):
    """x > c  ==  c - x < 0."""
    return lt_zero(ops, ops.sub_from_scalar(c, x))


def ge_scalar(ops, x, c: int):
    """x >= c  ==  NOT (x < c)."""
    return ops.sub_from_scalar(1, lt_scalar(ops, x, c))


def le_scalar(ops, x, c: int):
    return ops.sub_from_scalar(1, gt_scalar(ops, x, c))


def between_scalar(ops, x, lo: int, hi: int):
    """Paper §4.2.2 BETWEEN: product of the two one-sided masks (+1 depth)."""
    return ops.mul(ge_scalar(ops, x, lo), le_scalar(ops, x, hi))


def in_set(ops, x, values):
    """Paper Eq. 6: IN(x, S) = sum_{y in S} EQ(x, y), summed as a balanced
    tree (the §4.3.1 divide-and-conquer addition)."""
    terms = [eq_scalar(ops, x, int(v)) for v in values]
    return add_tree(ops, terms)


def add_tree(ops, terms: list):
    """Balanced binary addition tree (§4.3.1 BETWEEN/IN noise optimization)."""
    assert terms
    layer = list(terms)
    while len(layer) > 1:
        nxt = [ops.add(layer[i], layer[i + 1]) for i in range(0, len(layer) - 1, 2)]
        if len(layer) % 2:
            nxt.append(layer[-1])
        layer = nxt
    return layer[0]


def mul_tree(ops, terms: list):
    """Balanced product tree — depth log2(len) instead of len-1."""
    assert terms
    layer = list(terms)
    while len(layer) > 1:
        nxt = [ops.mul(layer[i], layer[i + 1]) for i in range(0, len(layer) - 1, 2)]
        if len(layer) % 2:
            nxt.append(layer[-1])
        layer = nxt
    return layer[0]


# Boolean algebra on {0,1} masks (paper Table 2 footnote).
def and_(ops, a, b):
    return ops.mul(a, b)


def or_(ops, a, b):
    return ops.sub(ops.add(a, b), ops.mul(a, b))


def not_(ops, a):
    return ops.sub_from_scalar(1, a)
