"""BFV parameter sets and precomputed tables.

NSHEDB (the paper) uses SEAL BFV with n = 32,768, log Q = 881, t = 65,537
(HE-standard 128-bit row).  We realize the same scheme in double-CRT (RNS)
form: Q is a product of 30-bit NTT-friendly primes so that all runtime
arithmetic is exact in int64 on the host path and exact in uint32
limb-arithmetic inside Pallas kernels (see kernels/modops).

Bases:
  Q  — the ciphertext base (k limbs).
  P  — the auxiliary base used by HPS RNS multiplication (k+1 limbs),
       P > n * Q / 2 guarantees the tensor product never wraps in Q∪P.

All tables are numpy/JAX arrays computed once per parameter set with exact
Python integer arithmetic (mathutil.py).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from .mathutil import (
    bit_reverse,
    find_ntt_primes,
    modinv,
    primitive_root,
    root_of_unity,
)

# Galois generator for slot rotations (standard BFV batching uses 3).
GALOIS_GEN = 3


@dataclasses.dataclass(frozen=True, eq=False)
class NttTables:
    """Per-base NTT tables: bit-reversed twiddles for CT/GS butterflies."""

    primes: tuple[int, ...]
    q: np.ndarray          # (k,) int64
    psi_rev: np.ndarray    # (k, n) int64  — psi^bitrev(i), psi a 2n-th root
    ipsi_rev: np.ndarray   # (k, n) int64  — psi^-bitrev(i)
    n_inv: np.ndarray      # (k,) int64    — n^-1 mod q

    @property
    def k(self) -> int:
        return len(self.primes)


def _make_ntt_tables(primes: list[int], n: int) -> NttTables:
    log_n = n.bit_length() - 1
    k = len(primes)
    psi_rev = np.zeros((k, n), dtype=np.int64)
    ipsi_rev = np.zeros((k, n), dtype=np.int64)
    n_inv = np.zeros((k,), dtype=np.int64)
    for li, q in enumerate(primes):
        psi = root_of_unity(2 * n, q)
        ipsi = modinv(psi, q)
        pw, ipw = 1, 1
        pws = np.zeros(n, dtype=np.int64)
        ipws = np.zeros(n, dtype=np.int64)
        for i in range(n):
            pws[i] = pw
            ipws[i] = ipw
            pw = pw * psi % q
            ipw = ipw * ipsi % q
        rev = np.array([bit_reverse(i, log_n) for i in range(n)])
        psi_rev[li] = pws[rev]
        ipsi_rev[li] = ipws[rev]
        n_inv[li] = modinv(n, q)
    return NttTables(
        primes=tuple(primes),
        q=np.array(primes, dtype=np.int64),
        psi_rev=psi_rev,
        ipsi_rev=ipsi_rev,
        n_inv=n_inv,
    )


@dataclasses.dataclass(frozen=True, eq=False)
class BaseConv:
    """Constants for exact HPS fast base conversion A -> B.

    For x given by residues x_i mod a_i with centered value X:
      y_i = x_i * AHatInv_i  mod a_i
      v   = round(sum_i y_i / a_i)                (float64)
      X   = sum_i y_i * AHat_i  -  v * A          (exact)
      out_j = (sum_i y_i * AHat_i - v*A) mod b_j
    """

    a_hat_inv_mod_a: np.ndarray  # (ka,)
    a_hat_mod_b: np.ndarray      # (ka, kb)
    a_mod_b: np.ndarray          # (kb,)
    a_inv: np.ndarray            # (ka,) float64 = 1/a_i


def _make_base_conv(a: list[int], b: list[int]) -> BaseConv:
    A = 1
    for ai in a:
        A *= ai
    a_hat = [A // ai for ai in a]
    return BaseConv(
        a_hat_inv_mod_a=np.array([modinv(h, ai) for h, ai in zip(a_hat, a)], dtype=np.int64),
        a_hat_mod_b=np.array([[h % bj for bj in b] for h in a_hat], dtype=np.int64),
        a_mod_b=np.array([A % bj for bj in b], dtype=np.int64),
        a_inv=np.array([1.0 / ai for ai in a], dtype=np.float64),
    )


@dataclasses.dataclass(frozen=True, eq=False)
class GaloisTable:
    """sigma_g in the coefficient domain: out[i] = sign[i] * a[src[i]]."""

    g: int
    src: np.ndarray   # (n,) int32
    sign: np.ndarray  # (n,) int64  (+1 / -1; applied then reduced mod q)


def _make_galois_table(g: int, n: int) -> GaloisTable:
    src = np.zeros(n, dtype=np.int32)
    sign = np.zeros(n, dtype=np.int64)
    for j in range(n):
        e = (j * g) % (2 * n)
        if e < n:
            src[e] = j
            sign[e] = 1
        else:
            src[e - n] = j
            sign[e - n] = -1
    return GaloisTable(g=g, src=src, sign=sign)


@dataclasses.dataclass(frozen=True, eq=False)
class HEParams:
    """A full BFV parameter set (immutable; hashable by id for jit caching)."""

    n: int
    t: int
    Q: NttTables
    P: NttTables
    T: NttTables                 # plaintext-modulus NTT (for batch encoding)
    conv_q_to_p: BaseConv
    conv_p_to_q: BaseConv
    delta_mod_q: np.ndarray      # (k,)  floor(Q/t) mod q_i
    q_inv_mod_p: np.ndarray      # (kp,) Q^-1 mod p_j
    q_mod_t: int                 # Q mod t (decryption integer-part constant)
    # Batch encoder slot maps.
    slot_to_coeff: np.ndarray    # (n,) int32: NTT-domain index of logical slot s
    # Galois tables: rotations by powers of two + row swap.
    galois: dict[int, GaloisTable]
    rot_gs: dict[int, int]       # rotation step (power of two) -> galois element
    rowswap_g: int
    # Error distribution.
    err_std: float = 3.2
    sec_level: int = 128

    # ---- derived ----
    @property
    def k(self) -> int:
        return self.Q.k

    @property
    def log_n(self) -> int:
        return self.n.bit_length() - 1

    @property
    def slots(self) -> int:
        return self.n

    @property
    def row(self) -> int:
        return self.n // 2

    @property
    def logQ(self) -> float:
        return float(sum(np.log2(np.array(self.Q.primes, dtype=np.float64))))

    def bigQ(self) -> int:
        Q = 1
        for q in self.Q.primes:
            Q *= q
        return Q

    @property
    def ct_bytes(self) -> int:
        """Wire size of one ciphertext (2 polys, k limbs, packed to limb width)."""
        bits_per_coeff = max(q.bit_length() for q in self.Q.primes)
        return 2 * self.k * self.n * ((bits_per_coeff + 7) // 8)

    def expansion_ratio(self, raw_bits: int = 16) -> float:
        """Ciphertext bytes per raw data byte when fully packed (paper: ~28x)."""
        raw_bytes = self.slots * raw_bits / 8
        return self.ct_bytes / raw_bytes


def _discrete_log_table(psi: int, t: int, order: int) -> dict[int, int]:
    tbl, w = {}, 1
    for e in range(order):
        tbl[w] = e
        w = w * psi % t
    return tbl


def _make_slot_map(n: int, t: int, T: NttTables) -> np.ndarray:
    """Map logical slot s -> NTT-output index k via numeric probing.

    NTT output position k holds the evaluation of the polynomial at
    psi_t^{e_k}; we discover e_k by transforming the basis polynomial X
    (whose evaluation at psi^e is psi^e itself) and reading discrete logs.
    Slots are laid out as 2 rows of n/2: row 0 slot j <-> exponent 3^j,
    row 1 slot j <-> exponent -3^j (mod 2n) — the standard BFV layout, so
    sigma_{3^r} rotates each row left by r and sigma_{2n-1} swaps rows.
    """
    from . import ntt as nttmod  # local import to avoid cycle

    x_poly = np.zeros((1, n), dtype=np.int64)
    x_poly[0, 1] = 1
    evals = np.asarray(
        nttmod.ntt_ref(x_poly, T.psi_rev[:1], T.q[:1])
    )[0]
    psi_t = root_of_unity(2 * n, t)
    dlog = _discrete_log_table(psi_t, t, 2 * n)
    e_of_k = np.array([dlog[int(v)] for v in evals])
    k_of_e = {int(e): k for k, e in enumerate(e_of_k)}
    slot_to_coeff = np.zeros(n, dtype=np.int32)
    half = n // 2
    e = 1
    for j in range(half):
        slot_to_coeff[j] = k_of_e[e]
        slot_to_coeff[half + j] = k_of_e[(2 * n - e) % (2 * n)]
        e = e * GALOIS_GEN % (2 * n)
    return slot_to_coeff


@lru_cache(maxsize=None)
def make_params(n: int = 4096, t: int = 65537, k: int = 6, qbits: int = 30) -> HEParams:
    """Construct a parameter set.

    n      ring degree (power of two); slots = n.
    t      plaintext modulus, prime with 2n | t-1 (needed for batching).
    k      number of 30-bit limbs in Q  (log Q ~ 30k).
    """
    assert n & (n - 1) == 0, "n must be a power of two"
    assert (t - 1) % (2 * n) == 0, f"batching needs 2n | t-1 (t={t}, n={n})"
    q_primes = find_ntt_primes(n, qbits, k, avoid=(t,))
    p_primes = find_ntt_primes(n, qbits + 1, k + 1, avoid=tuple(q_primes) + (t,))

    Q = _make_ntt_tables(q_primes, n)
    P = _make_ntt_tables(p_primes, n)
    T = _make_ntt_tables([t], n)

    bigQ = 1
    for q in q_primes:
        bigQ *= q
    bigP = 1
    for p in p_primes:
        bigP *= p
    assert bigP > n * bigQ // 2, "aux base too small for HPS tensor product"

    delta = bigQ // t
    delta_mod_q = np.array([delta % q for q in q_primes], dtype=np.int64)
    q_inv_mod_p = np.array([modinv(bigQ, p) for p in p_primes], dtype=np.int64)

    slot_to_coeff = _make_slot_map(n, t, T)

    # Galois elements: rotations by 2^j (within rows of n/2), plus row swap.
    rot_gs: dict[int, int] = {}
    galois: dict[int, GaloisTable] = {}
    step = 1
    while step < n // 2:
        g = pow(GALOIS_GEN, step, 2 * n)
        rot_gs[step] = g
        galois[g] = _make_galois_table(g, n)
        step *= 2
    rowswap_g = 2 * n - 1
    galois[rowswap_g] = _make_galois_table(rowswap_g, n)

    return HEParams(
        n=n,
        t=t,
        Q=Q,
        P=P,
        T=T,
        conv_q_to_p=_make_base_conv(q_primes, p_primes),
        conv_p_to_q=_make_base_conv(p_primes, q_primes),
        delta_mod_q=delta_mod_q,
        q_inv_mod_p=q_inv_mod_p,
        q_mod_t=bigQ % t,
        slot_to_coeff=slot_to_coeff,
        galois=galois,
        rot_gs=rot_gs,
        rowswap_g=rowswap_g,
    )


# ---------------------------------------------------------------------------
# Named parameter sets.
# ---------------------------------------------------------------------------

def test_params() -> HEParams:
    """Tiny, fast, full code path (used by unit tests). 2n=512 | 7680."""
    return make_params(n=256, t=7681, k=3)


def small_params() -> HEParams:
    """Medium set for integration tests / small benches. 2n=4096 | 65536."""
    return make_params(n=2048, t=65537, k=5)


def paper_params() -> HEParams:
    """The paper's production set: n=32768, t=65537, log Q ~ 881.

    30 limbs x ~29.4 effective bits ~ 884 bits — the HE-standard row the
    paper cites (n=32768 admits log Q up to 881 at 128-bit security; we
    match it to within one limb's rounding).
    """
    return make_params(n=32768, t=65537, k=30)
