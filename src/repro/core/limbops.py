"""Batched limb-level dispatch: Pallas kernels vs pure-jnp reference.

`LimbOps` binds one RNS base (an `NttTables`) and routes the hot
primitives of BFV evaluation — pointwise mul/add/sub-mod and the
forward/inverse negacyclic NTT — either through the Pallas kernels
(`kernels/modops`, `kernels/ntt`) or through the pure-jnp `*_ref`
oracles, selected by a backend flag:

    "ref"     exact int64 jnp arithmetic (always available)
    "pallas"  uint32 Barrett/Shoup kernels; interpret mode on CPU,
              compiled on TPU
    "auto"    "pallas" when running on a TPU, "ref" otherwise

The default comes from the NSHEDB_LIMB_BACKEND environment variable
("auto" if unset).  The Barrett path is tuned for primes in
(2^28, 2^30); bases outside that window (e.g. the 31-bit HPS auxiliary
base P) silently fall back to "ref" so a single flag can govern a whole
parameter set.

Every entry point accepts arrays of shape (..., k, n) — any number of
leading batch axes over the (limb, coefficient) layout — and is safe to
call from inside jit.  Batches are flattened to the (rows, n) layout the
kernels grid over, with the per-limb twiddle/modulus tables tiled to
match, so a whole column of ciphertext blocks runs as one kernel launch.
"""
from __future__ import annotations

import contextlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ntt as nttm
from .params import NttTables
from ..kernels.u32 import barrett_precompute
from ..kernels.modops.modops import add_mod_pallas, mul_mod_pallas, sub_mod_pallas
from ..kernels.ntt.ntt import ntt_fwd_pallas, ntt_inv_pallas

BACKENDS = ("ref", "pallas", "auto")

# Barrett window (kernels/u32.barrett_precompute): mu = 2^60/q < 2^32.
_Q_MIN, _Q_MAX = 1 << 28, 1 << 30


def default_backend() -> str:
    return os.environ.get("NSHEDB_LIMB_BACKEND", "auto")


# Depth of nested force_ref() contexts.  While > 0, every LimbOps call
# takes the jnp reference path regardless of the instance's backend flag.
_FORCE_REF = 0


@contextlib.contextmanager
def force_ref():
    """Route all limb primitives through the jnp reference path.

    shard_map bodies cannot host a Pallas interpret-mode launch (the
    interpreter's host callbacks do not trace under the per-shard
    closed-over mesh), so the sharded executor wraps shard-local
    evaluation in this context.  The flag is consulted at trace time:
    a function traced inside the context bakes in the ref path.
    """
    global _FORCE_REF
    _FORCE_REF += 1
    try:
        yield
    finally:
        _FORCE_REF -= 1


def pallas_supported(primes) -> bool:
    """True iff every modulus sits in the uint32 Barrett window."""
    return all(_Q_MIN < int(q) < _Q_MAX for q in primes)


def resolve_backend(backend: str | None, primes) -> str:
    """Normalize a user flag to the backend that will actually run."""
    b = backend or default_backend()
    if b not in BACKENDS:
        raise ValueError(f"unknown limb backend {b!r}; expected one of {BACKENDS}")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "ref"
    if b == "pallas" and not pallas_supported(primes):
        b = "ref"
    return b


class LimbLocalOps:
    """Per-device limb-slice primitives for shard_map bodies.

    Inside a `("data", "model")` shard_map region each device holds a
    contiguous (kL = k/M)-limb slice of every polynomial plus the
    matching slice of the twiddle/modulus tables, so the pointwise and
    NTT primitives are plain limb-major math over (..., kL, n) — zero
    communication (the all-gather of key-switch digits happens *before*
    these run; see core/bfv.py: kswitch_gathered).  Always ref-backed:
    Pallas interpret mode cannot trace inside shard_map, and the ref
    path is bit-identical anyway.
    """

    def __init__(self, q, psi, ipsi, ninv):
        self.q, self.psi, self.ipsi, self.ninv = q, psi, ipsi, ninv
        self.kl, self.n = psi.shape

    def _rows(self, a):
        """(..., kL, n) -> (B*kL, n) plus the batch factor B."""
        B = 1
        for d in a.shape[:-2]:
            B *= d
        return a.reshape(B * self.kl, self.n), B

    def _tile(self, tab, B: int):
        return jnp.concatenate([tab] * B, axis=0) if B > 1 else tab

    def mul(self, a, b):
        return (a * b) % self.q[:, None]

    def ntt(self, a):
        ar, B = self._rows(a)
        return nttm.ntt_ref(ar, self._tile(self.psi, B),
                            self._tile(self.q, B)).reshape(a.shape)

    def intt(self, a):
        ar, B = self._rows(a)
        return nttm.intt_ref(ar, self._tile(self.ipsi, B),
                             self._tile(self.ninv, B),
                             self._tile(self.q, B)).reshape(a.shape)


class LimbOps:
    """Pointwise + NTT primitives for one RNS base, kernel- or ref-backed."""

    def __init__(self, tables: NttTables, backend: str | None = None,
                 interpret: bool | None = None):
        self.tables = tables
        self.primes = tuple(int(q) for q in tables.primes)
        self.k = len(self.primes)
        self.n = tables.psi_rev.shape[1]
        self.backend = resolve_backend(backend, self.primes)
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        # ref tables (int64)
        self.q = jnp.asarray(tables.q)
        self.psi = jnp.asarray(tables.psi_rev)
        self.ipsi = jnp.asarray(tables.ipsi_rev)
        self.ninv = jnp.asarray(tables.n_inv)
        if self.backend == "pallas":
            q64 = np.asarray(tables.q, dtype=np.uint64)
            self._q_u32 = jnp.asarray(q64.astype(np.uint32))
            self._mu_u32 = jnp.asarray(
                np.array([barrett_precompute(q) for q in self.primes],
                         dtype=np.uint32))
            psi = np.asarray(tables.psi_rev, dtype=np.uint64)
            ipsi = np.asarray(tables.ipsi_rev, dtype=np.uint64)
            ninv = np.asarray(tables.n_inv, dtype=np.uint64)
            self._psi_u32 = jnp.asarray(psi.astype(np.uint32))
            self._psi_shoup = jnp.asarray(((psi << np.uint64(32)) // q64[:, None]).astype(np.uint32))
            self._ipsi_u32 = jnp.asarray(ipsi.astype(np.uint32))
            self._ipsi_shoup = jnp.asarray(((ipsi << np.uint64(32)) // q64[:, None]).astype(np.uint32))
            self._ninv_u32 = jnp.asarray(ninv.astype(np.uint32))
            self._ninv_shoup = jnp.asarray(((ninv << np.uint64(32)) // q64).astype(np.uint32))

    # --------------------------------------------------------- shape glue
    def _rows(self, a):
        """(..., k, n) -> (B*k, n) plus the batch factor B."""
        assert a.shape[-2:] == (self.k, self.n), (a.shape, self.k, self.n)
        B = 1
        for d in a.shape[:-2]:
            B *= d
        return a.reshape(B * self.k, self.n), B

    def _tile(self, tab, B: int):
        """Tile a per-limb table (k, ...) to (B*k, ...) row layout."""
        return jnp.concatenate([tab] * B, axis=0) if B > 1 else tab

    def _use_ref(self) -> bool:
        return self.backend == "ref" or _FORCE_REF > 0

    # ----------------------------------------------------- pointwise ops
    def _pointwise(self, a, b, kern_fn, ref_fn):
        shape = jnp.broadcast_shapes(a.shape, b.shape)
        a = jnp.broadcast_to(a, shape)
        b = jnp.broadcast_to(b, shape)
        if self._use_ref():
            return ref_fn(a.reshape(-1, self.n), b.reshape(-1, self.n)).reshape(shape)
        ar, B = self._rows(a)
        br, _ = self._rows(b)
        out = kern_fn(ar.astype(jnp.uint32), br.astype(jnp.uint32), B)
        return out.astype(jnp.int64).reshape(shape)

    def mul(self, a, b):
        """Pointwise a*b mod q over (..., k, n); exact, result in [0, q)."""
        return self._pointwise(
            a, b,
            lambda x, y, B: mul_mod_pallas(
                x, y, self._tile(self._q_u32[:, None], B),
                self._tile(self._mu_u32[:, None], B), interpret=self.interpret),
            lambda x, y: (x * y) % self._row_q(x))

    def add(self, a, b):
        return self._pointwise(
            a, b,
            lambda x, y, B: add_mod_pallas(
                x, y, self._tile(self._q_u32[:, None], B), interpret=self.interpret),
            lambda x, y: (x + y) % self._row_q(x))

    def sub(self, a, b):
        return self._pointwise(
            a, b,
            lambda x, y, B: sub_mod_pallas(
                x, y, self._tile(self._q_u32[:, None], B), interpret=self.interpret),
            lambda x, y: (x - y) % self._row_q(x))

    def _row_q(self, rows):
        """(B*k,) -> (B*k, 1) modulus column for flattened-row ref math."""
        B = rows.shape[0] // self.k
        return self._tile(self.q, B)[:, None]

    # -------------------------------------------------------------- NTT
    def ntt(self, a):
        """Forward negacyclic NTT over (..., k, n)."""
        shape = a.shape
        ar, B = self._rows(a)
        if self._use_ref():
            out = nttm.ntt_ref(ar, self._tile(self.psi, B), self._tile(self.q, B))
        else:
            out = ntt_fwd_pallas(
                ar.astype(jnp.uint32), self._tile(self._psi_u32, B),
                self._tile(self._psi_shoup, B), self._tile(self._q_u32[:, None], B),
                interpret=self.interpret).astype(jnp.int64)
        return out.reshape(shape)

    def intt(self, a):
        """Inverse negacyclic NTT over (..., k, n)."""
        shape = a.shape
        ar, B = self._rows(a)
        if self._use_ref():
            out = nttm.intt_ref(ar, self._tile(self.ipsi, B),
                                self._tile(self.ninv, B), self._tile(self.q, B))
        else:
            out = ntt_inv_pallas(
                ar.astype(jnp.uint32), self._tile(self._ipsi_u32, B),
                self._tile(self._ipsi_shoup, B), self._tile(self._q_u32[:, None], B),
                self._tile(self._ninv_u32[:, None], B),
                self._tile(self._ninv_shoup[:, None], B),
                interpret=self.interpret).astype(jnp.int64)
        return out.reshape(shape)
