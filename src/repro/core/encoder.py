"""BFV batch encoder: n integer slots per plaintext polynomial.

Slots are the CRT components of R_t = Z_t[X]/(X^n+1) (t prime, 2n | t-1),
laid out as 2 rows x n/2 columns so that the Galois element 3^r rotates
rows by r and 2n-1 swaps rows (see params._make_slot_map).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import ntt as nttm
from .params import HEParams


class BatchEncoder:
    def __init__(self, params: HEParams):
        self.params = params
        T = params.T
        self.qt = jnp.asarray(T.q)
        self.psi = jnp.asarray(T.psi_rev)
        self.ipsi = jnp.asarray(T.ipsi_rev)
        self.ninv = jnp.asarray(T.n_inv)
        self.slot_to_coeff = jnp.asarray(params.slot_to_coeff)
        # inverse permutation: coeff index -> slot
        inv = np.zeros(params.n, dtype=np.int32)
        inv[np.asarray(params.slot_to_coeff)] = np.arange(params.n)
        self.coeff_to_slot = jnp.asarray(inv)

    def encode(self, values) -> jnp.ndarray:
        """values: up to n ints (taken mod t); returns plaintext poly (n,)."""
        p = self.params
        vals = jnp.asarray(values, dtype=jnp.int64) % p.t
        if vals.shape[0] < p.n:
            vals = jnp.concatenate([vals, jnp.zeros(p.n - vals.shape[0], dtype=jnp.int64)])
        evals = vals[self.coeff_to_slot][None, :]
        poly = nttm.intt_ref(evals, self.ipsi, self.ninv, self.qt)
        return poly[0]

    def decode(self, poly: jnp.ndarray) -> jnp.ndarray:
        evals = nttm.ntt_ref(poly[None, :], self.psi, self.qt)[0]
        return evals[self.slot_to_coeff]

    def decode_signed(self, poly: jnp.ndarray) -> jnp.ndarray:
        """Decode with centered representatives in (-t/2, t/2]."""
        v = self.decode(poly)
        t = self.params.t
        return v - t * (v > t // 2)

    # Common mask plaintexts -------------------------------------------------
    def constant(self, c: int) -> jnp.ndarray:
        return self.encode(jnp.full(self.params.n, c, dtype=jnp.int64))

    def basis(self, slot: int) -> jnp.ndarray:
        """All-zeros except a single 1 at `slot` (the paper's Extract mask)."""
        v = jnp.zeros(self.params.n, dtype=jnp.int64).at[slot].set(1)
        return self.encode(v)
