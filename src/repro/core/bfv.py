"""RNS-BFV scheme (HPS multiplication variant), JAX-native.

Layout conventions
------------------
* polynomial:  (k, n) int64, limb-major, coefficients in [0, q_i)
* ciphertext:  (2, k, n) — (c0, c1), coefficient domain
* block batch: (nblocks, 2, k, n) — a whole column of ciphertext blocks
               stacked on a leading axis (`CiphertextBatch`)
* keys:        stored in NTT (evaluation) domain
* key switch:  per-limb RNS gadget (digit i = centered residue mod q_i);
               the gadget matrix g_i mod q_j is exactly the identity, so
               the "encrypt g_i * s'" term touches only limb i.

Batched evaluation path
-----------------------
Every arithmetic impl below is written against trailing (2, k, n) axes
and broadcasts over any leading batch axes, so the same jitted code
serves one ciphertext or a stacked column of blocks (one compilation per
shape).  The limb-level hot loops — pointwise RNS mul/add/sub and the
forward/inverse NTT — are routed through `core/limbops.LimbOps`, which
dispatches to the Pallas kernels (`kernels/modops`, `kernels/ntt`) or to
the pure-jnp `*_ref` oracles depending on the `backend` flag passed to
`BFVContext` (default: the NSHEDB_LIMB_BACKEND env var, "auto" = Pallas
on TPU, ref elsewhere; pass `interpret=True` to force kernel interpret
mode on CPU).  Both paths produce bit-identical residues, so decryption
results do not depend on the dispatch choice.

All deterministic arithmetic is jitted; sampling happens host-side with a
seeded numpy Generator so tests are reproducible.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from .limbops import LimbLocalOps, LimbOps
from .mathutil import centered, crt_reconstruct
from .noise import NoiseModel
from .params import HEParams


@dataclasses.dataclass
class Ciphertext:
    data: jnp.ndarray        # (2, k, n) int64, coefficient domain
    noise: float             # analytic log2 |invariant noise|
    params: HEParams

    @property
    def budget(self) -> float:
        return -(self.noise + 1.0)


@dataclasses.dataclass
class CiphertextBatch:
    """A stacked column of ciphertext blocks with one shared op history.

    data is (nblocks, 2, k, n).  Blocks of an encrypted column go through
    identical circuits, so a single analytic noise scalar — the max over
    the stacked blocks — serves the whole batch.  When block noises do
    differ (e.g. after a validity multiply on the last block), `noise`
    is a per-block numpy vector of length `nblocks` instead, which lets
    `_maybe_refresh`/`ensure_levels` refresh only the exhausted lanes
    rather than paying a conservative-max penalty for the whole batch.

    `live` supports sharded execution (engine/sharded.py): when the lane
    count is padded up to a multiple of the shard count with zero
    blocks, `live` records the logical block count.  `nblocks` reports
    the live count (so OpStats/noise accounting stay byte-identical to
    the unpadded path) while `nphys` reports the padded leading axis.
    """
    data: jnp.ndarray        # (nblocks, 2, k, n) int64
    noise: "float | np.ndarray"
    params: HEParams
    live: int | None = None

    @property
    def nblocks(self) -> int:
        return self.live if self.live is not None else self.data.shape[0]

    @property
    def nphys(self) -> int:
        return self.data.shape[0]

    @property
    def budget(self) -> float:
        return float(-(np.max(self.noise) + 1.0))


@dataclasses.dataclass
class SecretKey:
    s: np.ndarray            # (n,) ternary
    s_ntt: jnp.ndarray       # (k, n)


@dataclasses.dataclass
class PublicKey:
    b_ntt: jnp.ndarray       # (k, n)
    a_ntt: jnp.ndarray       # (k, n)


@dataclasses.dataclass
class KSwitchKey:
    b: jnp.ndarray           # (k, k, n) NTT domain, digit-major
    a: jnp.ndarray           # (k, k, n)


@dataclasses.dataclass
class Keys:
    sk: SecretKey
    pk: PublicKey
    rlk: KSwitchKey
    gks: dict[int, KSwitchKey]   # galois element -> key


@functools.partial(jax.jit, static_argnames=("mesh", "data_sharded"))
def _ksw_gathered(poly, kb, ka, q, psi, ipsi, ninv, *, mesh, data_sharded):
    """shard_map key-switch on a ("data", "model") mesh (see
    BFVContext.kswitch_gathered for the math).  poly is (B, k, n); the
    key is sharded on its *output*-limb axis 1, the tables on their limb
    axis, and the batch on "data" when B divides the data axis (a
    replicated batch — singletons, odd sizes — uses a None spec; the
    digit gather over "model" is the only hand-placed collective either
    way)."""
    P = jax.sharding.PartitionSpec
    dspec = "data" if data_sharded else None

    def body(p, kbl, kal, ql, psil, ipsil, ninvl):
        half = ql // 2
        cent = p - ql[:, None] * (p > half[:, None])                # (B, kL, n)
        gath = jax.lax.all_gather(cent, "model", axis=1, tiled=True)  # (B, k, n)
        digits = gath[:, :, None, :] % ql[None, None, :, None]      # (B, k, kL, n)
        ops = LimbLocalOps(ql, psil, ipsil, ninvl)
        d_ntt = ops.ntt(digits)
        acc_b = jnp.sum(ops.mul(d_ntt, kbl[None]), axis=1) % ql[:, None]
        acc_a = jnp.sum(ops.mul(d_ntt, kal[None]), axis=1) % ql[:, None]
        return ops.intt(acc_b), ops.intt(acc_a)

    specs = (P(dspec, "model", None), P(None, "model", None),
             P(None, "model", None), P("model"), P("model", None),
             P("model", None), P("model"))
    return shard_map(body, mesh=mesh, in_specs=specs,
                     out_specs=(P(dspec, "model", None),
                                P(dspec, "model", None)))(
        poly, kb, ka, q, psi, ipsi, ninv)


class BFVContext:
    """Binds a parameter set; owns jitted primitives and key material ops.

    `backend` / `interpret` select the limb-level execution path (see
    module docstring); all ciphertext ops accept `Ciphertext` and
    `CiphertextBatch` interchangeably and preserve the input type.
    """

    def __init__(self, params: HEParams, seed: int = 0,
                 backend: str | None = None, interpret: bool | None = None):
        self.params = params
        self.noise_model = NoiseModel(params)
        self.rng = np.random.default_rng(seed)
        p = params
        self.limb_q = LimbOps(p.Q, backend=backend, interpret=interpret)
        self.limb_p = LimbOps(p.P, backend=backend, interpret=interpret)
        self.qQ = jnp.asarray(p.Q.q)
        self.psiQ = jnp.asarray(p.Q.psi_rev)
        self.ipsiQ = jnp.asarray(p.Q.ipsi_rev)
        self.ninvQ = jnp.asarray(p.Q.n_inv)
        self.qP = jnp.asarray(p.P.q)
        self.psiP = jnp.asarray(p.P.psi_rev)
        self.ipsiP = jnp.asarray(p.P.ipsi_rev)
        self.ninvP = jnp.asarray(p.P.n_inv)
        self.delta = jnp.asarray(p.delta_mod_q)          # (k,)
        self.qinv_p = jnp.asarray(p.q_inv_mod_p)         # (kp,)
        cqp, cpq = p.conv_q_to_p, p.conv_p_to_q
        self.c_qp = tuple(jnp.asarray(x) for x in
                          (cqp.a_hat_inv_mod_a, cqp.a_hat_mod_b, cqp.a_mod_b, cqp.a_inv))
        self.c_pq = tuple(jnp.asarray(x) for x in
                          (cpq.a_hat_inv_mod_a, cpq.a_hat_mod_b, cpq.a_mod_b, cpq.a_inv))
        self._galois_tabs = {
            g: (jnp.asarray(tab.src), jnp.asarray(tab.sign)) for g, tab in p.galois.items()
        }
        # jitted primitives (shape-polymorphic: recompiled per batch shape)
        self._ntt_q = jax.jit(self.limb_q.ntt)
        self._intt_q = jax.jit(self.limb_q.intt)
        self._encrypt_j = jax.jit(self._encrypt_impl)
        self._decrypt_j = jax.jit(self._decrypt_impl)
        self._mul_j = jax.jit(self._mul_impl)
        self._mul_tensor_j = jax.jit(self._mul_tensor_impl)
        self._mul_plain_j = jax.jit(self._mul_plain_impl)
        self._apply_galois_j = jax.jit(self._apply_galois_impl, static_argnums=1)

    # --------------------------------------------------------- type glue
    @staticmethod
    def _like(ref, data, noise):
        """Result wrapper preserving Ciphertext vs CiphertextBatch type."""
        return dataclasses.replace(ref, data=data, noise=noise)

    @staticmethod
    def _pick(a, b):
        """Of two operands, the one whose type the result should take
        (the batched one, when single and batch are mixed)."""
        return a if a.data.ndim >= b.data.ndim else b

    @staticmethod
    def pack_noises(noises: list) -> "float | np.ndarray":
        """Scalar when uniform (the common case), else a per-block vector."""
        vals = [float(v) for v in noises]
        if all(v == vals[0] for v in vals):
            return vals[0]
        return np.asarray(vals, dtype=np.float64)

    def stack_cts(self, cts: list) -> CiphertextBatch:
        """Stack single-block ciphertexts into one batch (pure layout)."""
        assert cts and all(isinstance(c, Ciphertext) for c in cts)
        return CiphertextBatch(jnp.stack([c.data for c in cts]),
                               self.pack_noises([c.noise for c in cts]),
                               self.params)

    def unstack_cts(self, batch: CiphertextBatch) -> list:
        per = batch.noise if np.ndim(batch.noise) else None
        return [Ciphertext(batch.data[i],
                           float(per[i]) if per is not None else batch.noise,
                           self.params)
                for i in range(batch.nblocks)]

    # ------------------------------------------------------------- sampling
    def _sample_uniform_ntt(self) -> jnp.ndarray:
        p = self.params
        cols = [self.rng.integers(0, q, p.n, dtype=np.int64) for q in p.Q.primes]
        return jnp.asarray(np.stack(cols))

    def _sample_ternary(self) -> np.ndarray:
        return self.rng.integers(-1, 2, self.params.n).astype(np.int64)

    def _sample_err(self) -> np.ndarray:
        e = np.rint(self.rng.normal(0.0, self.params.err_std, self.params.n))
        bound = math.ceil(6 * self.params.err_std)
        return np.clip(e, -bound, bound).astype(np.int64)

    def _reduce_small(self, poly: np.ndarray) -> jnp.ndarray:
        """(n,) small centered ints -> (k, n) residues."""
        return jnp.asarray(poly[None, :] % np.asarray(self.params.Q.primes)[:, None])

    # -------------------------------------------------------------- keygen
    def keygen(self, galois_steps: tuple[int, ...] | None = None) -> Keys:
        p = self.params
        s = self._sample_ternary()
        s_ntt = self._ntt_q(self._reduce_small(s))
        a_ntt = self._sample_uniform_ntt()
        e_ntt = self._ntt_q(self._reduce_small(self._sample_err()))
        b_ntt = (-(a_ntt * s_ntt % self.qQ[:, None]) - e_ntt) % self.qQ[:, None]
        pk = PublicKey(b_ntt=b_ntt, a_ntt=a_ntt)
        sk = SecretKey(s=s, s_ntt=s_ntt)

        s2_ntt = (s_ntt * s_ntt) % self.qQ[:, None]
        rlk = self._make_kswitch_key(s_ntt, s2_ntt)

        gks: dict[int, KSwitchKey] = {}
        steps = galois_steps if galois_steps is not None else tuple(p.rot_gs)
        gs = [p.rot_gs[st] for st in steps] + [p.rowswap_g]
        for g in gs:
            src, sign = self._galois_tabs[g]
            s_rot = np.asarray((sign * jnp.asarray(s)[src]))
            s_rot_ntt = self._ntt_q(self._reduce_small(s_rot))
            gks[g] = self._make_kswitch_key(s_ntt, s_rot_ntt)
        return Keys(sk=sk, pk=pk, rlk=rlk, gks=gks)

    def _make_kswitch_key(self, s_ntt: jnp.ndarray, target_ntt: jnp.ndarray) -> KSwitchKey:
        """KSK encrypting gadget(target): digit i carries target on limb i only."""
        p = self.params
        k = p.k
        bs, as_ = [], []
        for i in range(k):
            a_i = self._sample_uniform_ntt()
            e_i = self._ntt_q(self._reduce_small(self._sample_err()))
            b_i = (-(a_i * s_ntt % self.qQ[:, None]) - e_i) % self.qQ[:, None]
            b_i = b_i.at[i].set((b_i[i] + target_ntt[i]) % self.qQ[i])
            bs.append(b_i)
            as_.append(a_i)
        return KSwitchKey(b=jnp.stack(bs), a=jnp.stack(as_))

    # ------------------------------------------------------------- encrypt
    def encrypt(self, m_poly: jnp.ndarray, pk: PublicKey) -> Ciphertext:
        """m_poly: (n,) int64 mod t (use BatchEncoder to build it)."""
        u = self._reduce_small(self._sample_ternary())
        e0 = self._reduce_small(self._sample_err())
        e1 = self._reduce_small(self._sample_err())
        data = self._encrypt_j(jnp.asarray(m_poly), u, e0, e1, pk.b_ntt, pk.a_ntt)
        return Ciphertext(data=data, noise=self.noise_model.fresh(), params=self.params)

    def _encrypt_impl(self, m, u, e0, e1, pkb, pka):
        q = self.qQ[:, None]
        lq = self.limb_q
        u_ntt = lq.ntt(u)
        c0 = (lq.intt(lq.mul(pkb, u_ntt)) + e0 + self.delta[:, None] * m[None, :]) % q
        c1 = (lq.intt(lq.mul(pka, u_ntt)) + e1) % q
        return jnp.stack([c0, c1])

    def encrypt_zero(self, pk: PublicKey) -> Ciphertext:
        return self.encrypt(jnp.zeros(self.params.n, dtype=jnp.int64), pk)

    # ------------------------------------------------------------- decrypt
    def decrypt(self, ct, sk: SecretKey) -> jnp.ndarray:
        """Decrypt a Ciphertext -> (n,) or a CiphertextBatch -> (nb, n)."""
        return self._decrypt_j(ct.data, sk.s_ntt)

    def _decrypt_impl(self, data, s_ntt):
        p = self.params
        q = self.qQ[:, None]
        lq = self.limb_q
        c0, c1 = data[..., 0, :, :], data[..., 1, :, :]
        x = (c0 + lq.intt(lq.mul(lq.ntt(c1), s_ntt))) % q
        hat_inv, _, _, q_inv_f = self.c_qp
        y = x * hat_inv[:, None] % q
        yt = y * p.t
        int_part = jnp.sum(yt // q, axis=-2)
        frac = jnp.sum((yt % q).astype(jnp.float64) * q_inv_f[:, None], axis=-2)
        return (int_part + jnp.round(frac).astype(jnp.int64)) % p.t

    # ------------------------------------------------------- add/sub/neg
    def add(self, a, b):
        out = self._pick(a, b)
        return self._like(out, (a.data + b.data) % self.qQ[:, None],
                          self.noise_model.add(a.noise, b.noise))

    def sub(self, a, b):
        out = self._pick(a, b)
        return self._like(out, (a.data - b.data) % self.qQ[:, None],
                          self.noise_model.add(a.noise, b.noise))

    def neg(self, a):
        return self._like(a, (-a.data) % self.qQ[:, None], a.noise)

    def add_plain(self, a, m_poly: jnp.ndarray):
        m = jnp.asarray(m_poly)
        c0 = (a.data[..., 0, :, :] + self.delta[:, None] * m[None, :]) % self.qQ[:, None]
        return self._like(a, a.data.at[..., 0, :, :].set(c0),
                          self.noise_model.add(a.noise, a.noise))

    def sub_from_plain(self, m_poly: jnp.ndarray, a):
        """Encrypted (m - a)."""
        return self.add_plain(self.neg(a), m_poly)

    # ------------------------------------------------------ plain multiply
    def mul_plain(self, a, m_poly: jnp.ndarray):
        data = self._mul_plain_j(a.data, jnp.asarray(m_poly))
        return self._like(a, data, self.noise_model.mul_plain(a.noise))

    # ------------------------------------------------------ scalar constants
    def mul_scalar(self, a, c: int):
        """Multiply by the constant polynomial c — no NTT, tight noise growth."""
        c %= self.params.t
        data = (a.data * c) % self.qQ[:, None]
        return self._like(a, data, self.noise_model.mul_scalar(a.noise, c))

    def add_scalar(self, a, c: int):
        """Add the constant c to every slot.

        The batch encoding of the all-c vector is the constant polynomial c,
        so only coefficient 0 of c0 moves (by delta*c per limb)."""
        c %= self.params.t
        c0 = a.data[..., 0, :, :].at[..., 0].add(self.delta * c) % self.qQ[:, None]
        return self._like(a, a.data.at[..., 0, :, :].set(c0),
                          self.noise_model.add(a.noise, a.noise))

    def sub_from_scalar(self, c: int, a):
        """Encrypted (c - a) for scalar c."""
        return self.add_scalar(self.neg(a), c)

    def _mul_plain_impl(self, data, m):
        lq = self.limb_q
        if m.ndim == 2:
            # per-block plaintexts: m is (nblocks, n) against a
            # (nblocks, 2, k, n) batch (fused broadcast_slot extraction)
            m_ntt = lq.ntt(m[:, None, :] % self.qQ[None, :, None])
        else:
            m_ntt = lq.ntt(m[None, :] % self.qQ[:, None])
        out0 = lq.intt(lq.mul(lq.ntt(data[..., 0, :, :]), m_ntt))
        out1 = lq.intt(lq.mul(lq.ntt(data[..., 1, :, :]), m_ntt))
        return jnp.stack([out0, out1], axis=-3)

    # ------------------------------------------------- HPS base conversion
    @staticmethod
    def _fbc(x, conv, in_mod, out_mod):
        """Exact fast base conversion of the centered value of x.

        x: (..., ka, n) residues mod in_mod; conv: jnp'ed BaseConv tuple;
        out_mod: (kb,). Products stay < 2^62, exact in int64.
        """
        hat_inv, hat_mod_b, a_mod_b, a_inv = conv
        y = (x * hat_inv[:, None]) % in_mod[:, None]
        v = jnp.round(jnp.sum(y.astype(jnp.float64) * a_inv[:, None], axis=-2)).astype(jnp.int64)
        terms = (y[..., :, None, :] * hat_mod_b[:, :, None]) % out_mod[None, :, None]
        acc = jnp.sum(terms, axis=-3)                      # (..., kb, n) < ka * b_j
        out = (acc - v[..., None, :] * a_mod_b[:, None]) % out_mod[:, None]
        return out

    # ------------------------------------------------------- ct-ct multiply
    def mul(self, a, b, rlk: KSwitchKey, mesh=None):
        """HPS tensor + relinearization.  With a 2-D query mesh the
        relin key-switch all-gathers its decomposition digits over the
        mesh "model" axis (engine/sharded.py) — byte-identical output,
        different collective structure."""
        if mesh is None:
            data = self._mul_j(a.data, b.data, rlk.b, rlk.a)
        else:
            r0, r1, r2 = self._mul_tensor_j(a.data, b.data)
            ks0, ks1 = self.kswitch_gathered(r2, rlk, mesh)
            q = self.qQ[:, None]
            data = jnp.stack([(r0 + ks0) % q, (r1 + ks1) % q], axis=-3)
        nz = self.noise_model
        return self._like(self._pick(a, b), data,
                          nz.keyswitch(nz.mul(a.noise, b.noise)))

    def _mul_tensor_impl(self, da, db):
        """Steps 1-4 of the HPS multiply: the degree-2 tensor scaled back
        to base Q, before relinearization."""
        p = self.params
        qQ, qP = self.qQ, self.qP
        lq, lp = self.limb_q, self.limb_p
        a0, a1 = da[..., 0, :, :], da[..., 1, :, :]
        b0, b1 = db[..., 0, :, :], db[..., 1, :, :]
        # 1. lift to Q ∪ P
        aP = (self._fbc(a0, self.c_qp, qQ, qP), self._fbc(a1, self.c_qp, qQ, qP))
        bP = (self._fbc(b0, self.c_qp, qQ, qP), self._fbc(b1, self.c_qp, qQ, qP))
        # 2. NTT + tensor in both bases
        fa = [lq.ntt(a0), lq.ntt(a1)]
        fb = [lq.ntt(b0), lq.ntt(b1)]
        ga = [lp.ntt(aP[0]), lp.ntt(aP[1])]
        gb = [lp.ntt(bP[0]), lp.ntt(bP[1])]
        tq = [
            lq.intt(lq.mul(fa[0], fb[0])),
            lq.intt(lq.add(lq.mul(fa[0], fb[1]), lq.mul(fa[1], fb[0]))),
            lq.intt(lq.mul(fa[1], fb[1])),
        ]
        tp = [
            lp.intt(lp.mul(ga[0], gb[0])),
            lp.intt(lp.add(lp.mul(ga[0], gb[1]), lp.mul(ga[1], gb[0]))),
            lp.intt(lp.mul(gb[1], ga[1])),
        ]
        # 3. scale by t/Q exactly: r = (t*E - [tE]_Q) / Q, computed in base P
        rs = []
        for eq, ep in zip(tq, tp):
            rem_q = (eq * p.t) % qQ[:, None]
            rem_p = self._fbc(rem_q, self.c_qp, qQ, qP)
            r_p = ((ep * p.t - rem_p) % qP[:, None]) * self.qinv_p[:, None] % qP[:, None]
            rs.append(self._fbc(r_p, self.c_pq, qP, qQ))       # 4. back to base Q
        return rs[0], rs[1], rs[2]

    def _mul_impl(self, da, db, rlk_b, rlk_a):
        r0, r1, r2 = self._mul_tensor_impl(da, db)
        # 5. relinearize r2
        ks0, ks1 = self._kswitch_inner(r2, rlk_b, rlk_a)
        q = self.qQ[:, None]
        return jnp.stack([(r0 + ks0) % q, (r1 + ks1) % q], axis=-3)

    # --------------------------------------------------------- key switch
    def _kswitch_inner(self, poly, ksk_b, ksk_a):
        """Key-switch `poly` (coeff domain, (..., k, n)): coeff-domain pair."""
        q = self.qQ[:, None]
        qvec = self.qQ
        half = qvec // 2
        lq = self.limb_q
        cent = poly - qvec[:, None] * (poly > half[:, None])       # centered digits
        digits = cent[..., :, None, :] % qvec[None, :, None]       # (..., kd, k, n)
        d_ntt = lq.ntt(digits)
        acc_b = jnp.sum(lq.mul(d_ntt, ksk_b), axis=-3) % q
        acc_a = jnp.sum(lq.mul(d_ntt, ksk_a), axis=-3) % q
        return lq.intt(acc_b), lq.intt(acc_a)

    def kswitch_gathered(self, poly, ksk: KSwitchKey, mesh):
        """`_kswitch_inner` on a 2-D ("data", "model") mesh.

        Each device holds a (kL = k/M)-limb slice of `poly` and the
        output-limb slice of the key (KSwitchKey axis 1 is the output
        limb; axis 0, the digit, stays whole per device).  The centered
        digits — k*n int64 per block, the *minimal* cross-limb payload —
        all-gather along "model"; each device then reduces the gathered
        digits mod its local moduli, NTTs with its local tables,
        multiplies with its key slice, folds over the full digit axis
        and INTTs.  Same summation order, exact int64 throughout, so the
        output is byte-identical to the fused single-device path.
        """
        lead = poly.shape[:-2]
        B = math.prod(lead) if lead else 1
        p3 = poly.reshape((B,) + poly.shape[-2:])
        data_ax = mesh.shape.get("data", 1)
        data_sharded = B > 1 and B % data_ax == 0
        b, a = _ksw_gathered(p3, ksk.b, ksk.a, self.qQ, self.psiQ,
                             self.ipsiQ, self.ninvQ, mesh=mesh,
                             data_sharded=data_sharded)
        return b.reshape(poly.shape), a.reshape(poly.shape)

    # ------------------------------------------------------------ rotation
    def _apply_galois_impl(self, data, g: int):
        src, sign = self._galois_tabs[g]
        return (sign * data[..., src]) % self.qQ[:, None]

    def apply_galois(self, ct, g: int, gk: KSwitchKey, mesh=None):
        rot = self._apply_galois_j(ct.data, g)
        if mesh is None:
            ks0, ks1 = self._kswitch_inner(rot[..., 1, :, :], gk.b, gk.a)
        else:
            ks0, ks1 = self.kswitch_gathered(rot[..., 1, :, :], gk, mesh)
        c0 = (rot[..., 0, :, :] + ks0) % self.qQ[:, None]
        return self._like(ct, jnp.stack([c0, ks1], axis=-3),
                          self.noise_model.rotate(ct.noise))

    def rotate_rows(self, ct, step: int, gks: dict[int, KSwitchKey],
                    mesh=None):
        """Rotate both rows left by `step` (decomposed into power-of-two hops)."""
        p = self.params
        step %= p.row
        out = ct
        hop = 1
        while step:
            if step & 1:
                g = p.rot_gs[hop]
                out = self.apply_galois(out, g, gks[g], mesh=mesh)
            step >>= 1
            hop <<= 1
        return out

    def swap_rows(self, ct, gks: dict[int, KSwitchKey], mesh=None):
        g = self.params.rowswap_g
        return self.apply_galois(ct, g, gks[g], mesh=mesh)

    # --------------------------------------------------- slot-level helpers
    def sum_slots(self, ct, gks: dict[int, KSwitchKey]):
        """Rotate-and-add tree: every slot ends up holding the full sum.

        log2(n/2) row rotations + 1 row swap (paper §4.2.2 COUNT/SUM).
        """
        out = ct
        step = 1
        while step < self.params.row:
            out = self.add(out, self.rotate_rows(out, step, gks))
            step *= 2
        return self.add(out, self.swap_rows(out, gks))

    # ----------------------------------------------------- batched column API
    def add_many(self, a_cts: list, b_cts: list) -> list:
        """Blockwise a+b over two columns via one stacked call."""
        return self.unstack_cts(self.add(self.stack_cts(a_cts), self.stack_cts(b_cts)))

    def sub_many(self, a_cts: list, b_cts: list) -> list:
        return self.unstack_cts(self.sub(self.stack_cts(a_cts), self.stack_cts(b_cts)))

    def mul_plain_many(self, cts: list, m_poly: jnp.ndarray) -> list:
        """One plaintext polynomial against every block of a column."""
        return self.unstack_cts(self.mul_plain(self.stack_cts(cts), m_poly))

    def mul_many(self, a_cts: list, b_cts: list, rlk: KSwitchKey) -> list:
        """Blockwise ct-ct products (tensor + relin) in one stacked call."""
        return self.unstack_cts(self.mul(self.stack_cts(a_cts), self.stack_cts(b_cts), rlk))

    def rotate_rows_many(self, cts: list, step: int, gks: dict[int, KSwitchKey]) -> list:
        return self.unstack_cts(self.rotate_rows(self.stack_cts(cts), step, gks))

    def sum_slots_many(self, cts: list, gks: dict[int, KSwitchKey]) -> list:
        return self.unstack_cts(self.sum_slots(self.stack_cts(cts), gks))

    def fold_add(self, batch: CiphertextBatch) -> Ciphertext:
        """Sum a batch across its block axis into one ciphertext — the
        cross-block half of an aggregation.  Residues match the
        sequential add chain exactly (mod-q sums commute); the noise
        bound replays the same sequential `add` recurrence.  Only the
        `live` lanes participate: shard padding lanes may hold garbage
        after broadcasted single×batch ops and must never enter a sum."""
        nb = batch.nblocks
        data = jnp.sum(batch.data[:nb], axis=0) % self.qQ[:, None]
        per = batch.noise if np.ndim(batch.noise) else None
        noise = float(per[0]) if per is not None else batch.noise
        for i in range(1, nb):
            noise = self.noise_model.add(
                noise, float(per[i]) if per is not None else batch.noise)
        return Ciphertext(data, noise, self.params)

    # ------------------------------------------------------- noise measure
    def noise_budget_exact(self, ct: Ciphertext, sk: SecretKey) -> float:
        """Exact invariant-noise budget in bits (host-side bigint; tests)."""
        p = self.params
        q = self.qQ[:, None]
        lq = self.limb_q
        x = np.asarray((ct.data[0] + lq.intt(lq.mul(lq.ntt(ct.data[1]), sk.s_ntt))) % q)
        m = np.asarray(self._decrypt_j(ct.data, sk.s_ntt))
        Q = p.bigQ()
        tQ = p.t * Q
        worst = 1
        for j in range(p.n):
            X = crt_reconstruct([int(x[i, j]) for i in range(p.k)], list(p.Q.primes))
            w = centered((p.t * X - int(m[j]) * Q) % tQ, tQ)
            worst = max(worst, abs(w))
        return math.log2(Q) - 1.0 - math.log2(worst)
