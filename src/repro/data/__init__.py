"""Deterministic synthetic data pipeline (checkpointable)."""
from .pipeline import TokenPipeline  # noqa: F401
