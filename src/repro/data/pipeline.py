"""Deterministic, checkpointable synthetic token pipeline.

Each (step, shard) pair maps to an independent counter-mode stream —
restoring a checkpoint at step k reproduces exactly the batches a
never-interrupted run would have seen (the fault-tolerance contract),
and each data shard draws a disjoint stream (the multi-host contract).

The "text" is a deterministic Markov-ish mixture so the loss actually
decreases during the example training runs (pure uniform noise would
pin the loss at log V).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    batch: int
    shard: int = 0
    num_shards: int = 1
    seed: int = 1234
    step: int = 0                      # checkpointable cursor

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed, "shard": self.shard}

    def load_state_dict(self, st: dict) -> None:
        assert st["seed"] == self.seed and st["shard"] == self.shard, \
            "restoring a pipeline onto a different stream"
        self.step = int(st["step"])

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, self.shard, step]))

    def next_batch(self) -> dict:
        rng = self._rng(self.step)
        self.step += 1
        B, S, V = self.batch, self.seq_len, self.vocab
        # structured stream: tokens follow t_{i+1} = (a*t_i + b) mod V with
        # occasional resets — predictable enough for loss to fall.
        a = int(rng.integers(2, 64)) * 2 + 1
        starts = rng.integers(0, V, (B, 1))
        idx = np.arange(S + 1)
        toks = (starts + idx * a) % V
        noise = rng.random((B, S + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, V, (B, S + 1)), toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
