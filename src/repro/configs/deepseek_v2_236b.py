"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed
top-6 [arXiv:2405.04434].

60L d_model=5120 128H MLA, routed-expert d_ff=1536, vocab=102400.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", d_model=5120, n_layers=60, vocab=102400,
    n_heads=128, n_kv_heads=128, head_dim=128,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
    pattern=("attn",), d_ff=0,
    n_experts=160, n_experts_per_tok=6, n_shared_experts=2, moe_d_ff=1536,
    tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke", d_model=64, n_layers=2, vocab=128,
        n_heads=4, n_kv_heads=4, head_dim=16,
        use_mla=True, kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
        pattern=("attn",), d_ff=0,
        n_experts=8, n_experts_per_tok=2, n_shared_experts=1, moe_d_ff=48,
        capacity_factor=4.0,     # E/k: dropless at smoke scale (exactness tests)
        tie_embeddings=False)
