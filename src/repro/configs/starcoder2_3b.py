"""starcoder2-3b [dense] — GQA kv=2, RoPE [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.  (Upstream mixes
LN + learned positions in places; we keep the shared pre-RMSNorm + RoPE
stack — deviation noted in DESIGN.md.)
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", d_model=3072, n_layers=30, vocab=49152,
    n_heads=24, n_kv_heads=2, head_dim=128,
    pattern=("attn",), d_ff=12288, mlp_gated=False,
    tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", d_model=64, n_layers=2, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16,
        pattern=("attn",), d_ff=128, mlp_gated=False,
        tie_embeddings=True)
