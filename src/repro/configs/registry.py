"""Architecture registry + shape cells + input specs.

The 40 dry-run cells are (arch x its shape set); ``long_500k`` runs only
for sub-quadratic architectures (SSM / recurrent / local-dominated) and
is recorded as SKIP(full-attention) for the rest — per the assignment
shape note and DESIGN.md §5.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

_MODULES = {
    "mamba2-1.3b": "mamba2_1_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "phi3.5-moe-42b": "phi3_5_moe_42b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "gemma3-27b": "gemma3_27b",
    "qwen2-72b": "qwen2_72b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma2-27b": "gemma2_27b",
    "whisper-large-v3": "whisper_large_v3",
}

# Sub-quadratic archs that run the long_500k cell.
LONG_OK = {"mamba2-1.3b", "recurrentgemma-9b", "gemma3-27b", "gemma2-27b"}

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

ARCHS = list(_MODULES)


def _mod(arch: str):
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke()


def shape_cells(arch: str) -> list[tuple[str, str | None]]:
    """[(shape_name, skip_reason_or_None)] — all four, with skips marked."""
    out = []
    for name in SHAPES:
        if name == "long_500k" and arch not in LONG_OK:
            out.append((name, "SKIP(full-attention)"))
        else:
            out.append((name, None))
    return out


def input_specs(cfg: ModelConfig, shape: str, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    train  : tokens + labels (+ frontend stubs)
    prefill: tokens (+ stubs) — builds the cache
    decode : one new token + a filled cache of seq_len context
    """
    info = SHAPES[shape]
    S, B, kind = info["seq"], info["batch"], info["kind"]
    d = cfg.d_model
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    emb = lambda b, s: jax.ShapeDtypeStruct((b, s, d), dtype)

    specs: dict = {"kind": kind, "seq": S, "batch": B}
    if kind == "train":
        specs["tokens"] = tok(B, S)
        specs["labels"] = tok(B, S)
        if cfg.frontend == "vision":
            from .phi_3_vision_4_2b import N_PATCHES
            specs["patches"] = emb(B, N_PATCHES)
        if cfg.is_enc_dec:
            specs["enc_embeds"] = emb(B, max(S // 4, 128))
    elif kind == "prefill":
        specs["tokens"] = tok(B, S)
        if cfg.frontend == "vision":
            from .phi_3_vision_4_2b import N_PATCHES
            specs["patches"] = emb(B, N_PATCHES)
        if cfg.is_enc_dec:
            specs["enc_embeds"] = emb(B, max(S // 4, 128))
    else:  # decode: one token against a seq_len cache
        specs["tokens"] = tok(B, 1)
        specs["cache_len"] = S
        if cfg.is_enc_dec:
            specs["enc_embeds"] = emb(B, max(S // 4, 128))
    return specs
