"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048 attention-free, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, head_dim 64 -> 64 heads (Mamba2 defaults).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", d_model=2048, n_layers=48, vocab=50280,
    pattern=("ssm",), d_ff=0,
    ssm_state=128, ssm_heads=64, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", d_model=64, n_layers=2, vocab=128,
        pattern=("ssm",), d_ff=0,
        ssm_state=16, ssm_heads=4, ssm_head_dim=8, ssm_chunk=8,
        tie_embeddings=True)
