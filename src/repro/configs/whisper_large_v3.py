"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280, 20H (kv=20), d_ff=5120,
vocab=51866.  input_specs() provides precomputed frame embeddings
(enc_len = seq/4 for decode shapes) — the conv frontend is a stub.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", d_model=1280, n_layers=32, vocab=51866,
    n_heads=20, n_kv_heads=20, head_dim=64,
    pattern=("xdec",), d_ff=5120, mlp_act="gelu", mlp_gated=False,
    enc_layers=32, is_enc_dec=True, frontend="audio",
    tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", d_model=64, n_layers=2, vocab=128,
        n_heads=4, n_kv_heads=4, head_dim=16,
        pattern=("xdec",), d_ff=128, mlp_act="gelu", mlp_gated=False,
        enc_layers=2, is_enc_dec=True, frontend="audio",
        tie_embeddings=True)
