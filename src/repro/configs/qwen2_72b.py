"""qwen2-72b [dense] — GQA with QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", d_model=8192, n_layers=80, vocab=152064,
    n_heads=64, n_kv_heads=8, head_dim=128, qkv_bias=True,
    pattern=("attn",), d_ff=29568,
    rope_theta=1e6, tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", d_model=64, n_layers=2, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True,
        pattern=("attn",), d_ff=128,
        tie_embeddings=False)
