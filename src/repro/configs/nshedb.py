"""The paper's own workload as a dry-run "architecture".

A distributed encrypted table scan: EQ-mask (16-level Fermat square
chain with per-level relinearization) + mask multiply + rotate-reduce
aggregation over packed RNS-BFV ciphertext blocks.

Distribution (DESIGN.md §4): ciphertext blocks (table row-segments)
shard over (pod, data) — scan-first is embarrassingly parallel across
segments; RNS limbs shard over model.  Key-switching needs every digit
of the target polynomial on every limb shard -> all-gather over model;
the final aggregate psums over (pod, data).  That digit all-gather is
the collective-bound part of the workload and hillclimb target #3.

k = 32 limbs (instead of SEAL's 30) so limbs divide the 16-way model
axis: logQ ~ 32 x 27.6 = 883 bits — the same HE-standard 128-bit budget
as the paper's logQ = 881 (DESIGN.md §3 hardware-adaptation table).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NshedbConfig:
    name: str = "nshedb"
    n: int = 32768            # ring degree (slots per ciphertext)
    k: int = 32               # RNS limbs (divisible by model=16)
    t: int = 65537
    eq_levels: int = 16       # ceil(log2(t-1)) square chain
    rot_steps: int = 15       # log2(n/2) rotate-reduce


CONFIG = NshedbConfig()

# shape cells for the paper workload: blocks = table segments of 32768
# rows each (SF~30 lineitem = 200M rows ~ 6144 blocks).
#   _pagg: partial aggregation (perf iteration #3a) — stop the
#          rotate-reduce at chunk 32 (5 hops instead of 15); the client
#          combines n/32 exact partials.  10 fewer key-switches/block.
#   _rs:   key-switch products constrained digit-local + tree-reduced
#          (reduce-scatter formulation) instead of digit all-gather.
SHAPES = {
    "scan_2m": dict(nblocks=64),       # 2.1M rows  — one block per device
    "scan_33m": dict(nblocks=1024),    # 33.6M rows — 32 blocks per shard
    "scan_33m_pagg": dict(nblocks=1024, rot_steps=5),
    "scan_33m_rs": dict(nblocks=1024, ks_mode="reduce_scatter"),
}


def smoke() -> NshedbConfig:
    return NshedbConfig(name="nshedb-smoke", n=256, k=4, t=257,
                        eq_levels=8, rot_steps=7)
