"""Assigned-architecture configs (one module per arch) + registry."""
from .registry import ARCHS, get_config, get_smoke_config, input_specs, shape_cells  # noqa: F401
