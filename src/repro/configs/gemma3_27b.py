"""gemma3-27b [dense] — 5:1 local:global, qk-norm, 128k context
[hf:google/gemma-3-*].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; window 1024.
62 = 10 x (5 local + 1 global) + 2 tail locals.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", d_model=5376, n_layers=62, vocab=262144,
    n_heads=32, n_kv_heads=16, head_dim=128, qk_norm=True,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024, d_ff=21504, mlp_act="gelu",
    tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", d_model=64, n_layers=8, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True,
        pattern=("local", "local", "local", "local", "local", "attn"),
        window=16, d_ff=128, mlp_act="gelu",
        tie_embeddings=True)
