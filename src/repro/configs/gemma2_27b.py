"""gemma2-27b [dense] — 1:1 local:global alternation + logit softcaps
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; window 4096;
attention softcap 50, final-logit softcap 30.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", d_model=4608, n_layers=46, vocab=256000,
    n_heads=32, n_kv_heads=16, head_dim=128,
    pattern=("local", "attn"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    d_ff=36864, mlp_act="gelu",
    tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", d_model=64, n_layers=4, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16,
        pattern=("local", "attn"), window=16,
        attn_softcap=50.0, logit_softcap=30.0,
        d_ff=128, mlp_act="gelu",
        tie_embeddings=True)
