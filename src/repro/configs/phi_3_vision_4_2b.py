"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend stub
[hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.  input_specs()
provides precomputed patch embeddings (256 x d_model) — the CLIP tower
is a stub per the assignment brief.
"""
from ..models.config import ModelConfig

N_PATCHES = 256

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", d_model=3072, n_layers=32, vocab=32064,
    n_heads=32, n_kv_heads=32, head_dim=96,
    pattern=("attn",), d_ff=8192,
    frontend="vision", tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-smoke", d_model=64, n_layers=2, vocab=128,
        n_heads=4, n_kv_heads=4, head_dim=16,
        pattern=("attn",), d_ff=128,
        frontend="vision", tie_embeddings=True)
