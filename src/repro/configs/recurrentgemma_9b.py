"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; window 2048;
lru_width = d_model; pattern (rglru, rglru, local) -> 12 units + 2 tail.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", d_model=4096, n_layers=38, vocab=256000,
    n_heads=16, n_kv_heads=1, head_dim=256,
    pattern=("rglru", "rglru", "local"), window=2048,
    d_ff=12288, mlp_act="gelu", lru_width=4096,
    tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", d_model=64, n_layers=5, vocab=128,
        n_heads=4, n_kv_heads=1, head_dim=16,
        pattern=("rglru", "rglru", "local"), window=16,
        d_ff=128, mlp_act="gelu", lru_width=64,
        tie_embeddings=True)
