"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) expert d_ff=6400 vocab=32064.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b", d_model=4096, n_layers=32, vocab=32064,
    n_heads=32, n_kv_heads=8, head_dim=128,
    pattern=("attn",), d_ff=0,
    n_experts=16, n_experts_per_tok=2, moe_d_ff=6400,
    tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", d_model=64, n_layers=2, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16,
        pattern=("attn",), d_ff=0,
        n_experts=4, n_experts_per_tok=2, moe_d_ff=96,
        capacity_factor=2.0,     # E/k: dropless at smoke scale (exactness tests)
        tie_embeddings=False)
