"""Training driver: config -> mesh -> sharded train loop with
checkpoint/restart, straggler heartbeats and optional gradient
compression.

Runs on whatever devices exist (the CPU dev box trains reduced configs;
the same code on a pod trains full ones):

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..data.pipeline import TokenPipeline
from ..dist.sharding import input_sharding, param_sharding
from ..models import lm
from ..runtime.checkpoint import CheckpointManager
from ..runtime.elastic import StragglerDetector
from ..train import steps as steps_mod
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh else make_host_mesh())
    print(f"arch={cfg.name} params={lm.param_count(cfg):,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    step_fn = steps_mod.make_train_step(cfg, lr=args.lr,
                                        compress_grads=args.compress_grads)

    with mesh:
        params = lm.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        pshard = param_sharding(jax.eval_shape(lambda: params), mesh)
        params = jax.tree.map(jax.device_put, params, pshard)
        opt = steps_mod.init_opt(cfg, params, compress_grads=args.compress_grads)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            s = ckpt.latest_step()
            params, opt, extra = ckpt.restore(s, params, opt)
            pipe.load_state_dict(extra["pipeline"])
            start = s
            print(f"resumed from step {s}")

        detector = StragglerDetector()
        losses = []
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
            t0 = time.perf_counter()
            params, opt, metrics = jstep(params, opt, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            detector.report(worker=0, step_time=dt)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:8.4f} {dt*1e3:7.1f} ms")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, params, opt,
                          extra={"pipeline": pipe.state_dict()})
        if ckpt:
            ckpt.save(args.steps, params, opt,
                      extra={"pipeline": pipe.state_dict()})
            ckpt.wait()
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()
