"""Distributed encrypted-scan step (the paper's workload on the mesh).

query_step(cts, keys):  for every ciphertext block — a packed table
segment in the NTT (evaluation) domain — evaluate

  mask  = EQ(column, const)  : eq_levels pointwise squarings, each
                               followed by an RNS key-switch
  out   = mask * values      : one more multiply + key-switch
  aggregate                  : rotate-reduce (rot_steps Galois hops, each
                               another key-switch) then psum over blocks

All modular arithmetic is uint32 Barrett (kernels/u32) — the same
code that runs inside the Pallas kernels, so the dry-run HLO reflects
the real integer op mix.  Sharding: blocks over (pod, data); limbs over
model.  The key-switch digit product contracts over *all* limbs, which
GSPMD turns into the all-gather over model that dominates the
collective roofline term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.nshedb import NshedbConfig
from ..kernels import u32


def make_constants(cfg: NshedbConfig):
    """Host-side: RNS primes + Barrett mus + a Galois permutation table."""
    from ..core.mathutil import find_ntt_primes
    primes = find_ntt_primes(cfg.n, 30, cfg.k)
    q = np.array(primes, dtype=np.uint32)
    mu = np.array([(1 << 60) // int(p) for p in primes], dtype=np.uint32)
    rng = np.random.default_rng(0)
    perm = rng.permutation(cfg.n).astype(np.int32)   # stand-in Galois map
    return {"q": q, "mu": mu, "perm": perm}


KS_MODE = "all_gather"      # | "reduce_scatter" (perf iteration #3b)


def _tree_fold(prod, q):
    """Halving-tree modular sum over the digit axis (log2 k rounds of
    elementwise add_mod — shard-friendly, unlike a serial scan)."""
    kd = prod.shape[0]
    while kd > 1:
        half = kd // 2
        lo, hi = prod[:half], prod[half:kd]
        if hi.shape[0] < lo.shape[0]:
            hi = jnp.concatenate([hi, jnp.zeros_like(lo[: lo.shape[0] - hi.shape[0]])])
        prod = u32.add_mod(lo, hi, q[None, :, None])
        kd = half
    return prod[0]


def keyswitch(poly, ksk_b, ksk_a, q, mu, mode: str = None):
    """RNS key-switch of `poly` (k, n): digit-major gadget product.

    all_gather mode: every output limb needs every input digit -> the
    digit contraction becomes the model-axis all-gather dominating the
    collective roofline term.  reduce_scatter mode constrains products
    digit-local and tree-reduces across shards instead (measured in perf
    iteration #3b)."""
    mode = mode or KS_MODE
    digits = poly[:, None, :]                        # (k_digit, 1, n)
    prod_b = u32.barrett_mulmod(digits, ksk_b, q[None, :, None], mu[None, :, None])
    prod_a = u32.barrett_mulmod(digits, ksk_a, q[None, :, None], mu[None, :, None])
    if mode == "reduce_scatter":
        from jax.sharding import PartitionSpec as P
        cons = lambda x: jax.lax.with_sharding_constraint(x, P("model", None, None))
        prod_b, prod_a = cons(prod_b), cons(prod_a)
    return _tree_fold(prod_b, q), _tree_fold(prod_a, q)


def ct_square(ct, rlk_b, rlk_a, q, mu, mode=None):
    """Evaluation-domain ciphertext squaring + relinearization.
    ct: (2, k, n) uint32."""
    c0, c1 = ct[0], ct[1]
    d0 = u32.barrett_mulmod(c0, c0, q[:, None], mu[:, None])
    d1 = u32.barrett_mulmod(c0, c1, q[:, None], mu[:, None])
    d1 = u32.add_mod(d1, d1, q[:, None])
    d2 = u32.barrett_mulmod(c1, c1, q[:, None], mu[:, None])
    ks0, ks1 = keyswitch(d2, rlk_b, rlk_a, q, mu, mode)
    return jnp.stack([u32.add_mod(d0, ks0, q[:, None]),
                      u32.add_mod(d1, ks1, q[:, None])])


def ct_mul(ct_a, ct_b, rlk_b, rlk_a, q, mu, mode=None):
    a0, a1 = ct_a[0], ct_a[1]
    b0, b1 = ct_b[0], ct_b[1]
    qq, mm = q[:, None], mu[:, None]
    d0 = u32.barrett_mulmod(a0, b0, qq, mm)
    d1 = u32.add_mod(u32.barrett_mulmod(a0, b1, qq, mm),
                     u32.barrett_mulmod(a1, b0, qq, mm), qq)
    d2 = u32.barrett_mulmod(a1, b1, qq, mm)
    ks0, ks1 = keyswitch(d2, rlk_b, rlk_a, q, mu, mode)
    return jnp.stack([u32.add_mod(d0, ks0, qq), u32.add_mod(d1, ks1, qq)])


def rotate(ct, perm, gk_b, gk_a, q, mu, mode=None):
    """Galois rotation: coefficient permutation + key switch."""
    rot = ct[:, :, perm]
    ks0, ks1 = keyswitch(rot[1], gk_b, gk_a, q, mu, mode)
    return jnp.stack([u32.add_mod(rot[0], ks0, q[:, None]), ks1])


def query_step(cts_col, cts_val, rlk_b, rlk_a, gk_b, gk_a, q, mu, perm,
               *, eq_levels: int, rot_steps: int, ks_mode: str = None):
    """cts_col/cts_val: (nblocks, 2, k, n) uint32 — EQ-mask the column,
    multiply the values, rotate-reduce, then sum across blocks."""

    def per_block(col, val):
        mask = col
        for _ in range(eq_levels):
            mask = ct_square(mask, rlk_b, rlk_a, q, mu, ks_mode)
        out = ct_mul(mask, val, rlk_b, rlk_a, q, mu, ks_mode)
        for _ in range(rot_steps):
            rot = rotate(out, perm, gk_b, gk_a, q, mu, ks_mode)
            out = jnp.stack([u32.add_mod(out[0], rot[0], q[:, None]),
                             u32.add_mod(out[1], rot[1], q[:, None])])
        return out

    outs = jax.vmap(per_block)(cts_col, cts_val)
    # binary-tree modular block aggregation: log2(nb) elementwise halving
    # rounds — the sharded block axis reduces via collectives, not a
    # serial chain.
    nb = outs.shape[0]
    while nb > 1:
        half = nb // 2
        outs = u32.add_mod(outs[:half], outs[half:nb], q[None, None, :, None])
        nb = half
    return outs[0]


def input_specs(cfg: NshedbConfig, nblocks: int):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    u = jnp.uint32
    ct = jax.ShapeDtypeStruct((nblocks, 2, cfg.k, cfg.n), u)
    ksk = jax.ShapeDtypeStruct((cfg.k, cfg.k, cfg.n), u)
    return {
        "cts_col": ct, "cts_val": ct,
        "rlk_b": ksk, "rlk_a": ksk, "gk_b": ksk, "gk_a": ksk,
        "q": jax.ShapeDtypeStruct((cfg.k,), u),
        "mu": jax.ShapeDtypeStruct((cfg.k,), u),
        "perm": jax.ShapeDtypeStruct((cfg.n,), jnp.int32),
    }


def shardings(mesh, cfg: NshedbConfig, nblocks: int):
    from jax.sharding import NamedSharding, PartitionSpec as P
    names = mesh.axis_names
    blocks = tuple(a for a in ("pod", "data") if a in names) or None
    model = "model" if "model" in names else None
    ns = lambda *sp: NamedSharding(mesh, P(*sp))
    return {
        "cts_col": ns(blocks, None, model, None),
        "cts_val": ns(blocks, None, model, None),
        # key-switch keys: digit axis replicated, output limb over model
        "rlk_b": ns(None, model, None), "rlk_a": ns(None, model, None),
        "gk_b": ns(None, model, None), "gk_a": ns(None, model, None),
        "q": ns(None), "mu": ns(None), "perm": ns(None),
    }
