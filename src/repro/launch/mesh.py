"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — required because the dry-run
must set XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_scan_mesh(shards: int):
    """1-D ("data",) mesh over the first `shards` devices.

    The sharded scan executor (engine/sharded.py) partitions stacked
    ciphertext-block columns over this axis; unlike make_host_mesh it
    takes an explicit shard count so elastic re-planning
    (runtime/elastic.py:elastic_scan_plan) can shrink the mesh after a
    straggler exclusion without restarting the process.
    """
    devs = jax.devices()
    if shards > len(devs):
        raise ValueError(f"requested {shards} shards but only "
                         f"{len(devs)} devices are visible")
    return jax.sharding.Mesh(np.array(devs[:shards]), ("data",))
