"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — required because the dry-run
must set XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
