"""Mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — required because the dry-run
must set XLA_FLAGS before the first jax initialization.

Every factory routes through one `_device_mesh` helper (DESIGN §4): the
first `prod(shape)` visible devices reshaped to the axis grid, so the
production, host, scan and query meshes all agree on device ordering —
a worker id on the flattened grid maps to the same physical device no
matter which factory built the mesh.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def _device_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """The one mesh constructor: first prod(shape) devices, row-major."""
    devs = jax.devices()
    need = math.prod(shape)
    if need > len(devs):
        raise ValueError(f"mesh {shape} over {axes} needs {need} devices "
                         f"but only {len(devs)} are visible")
    return jax.sharding.Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _device_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — smoke tests and examples."""
    return _device_mesh((len(jax.devices()),), ("data",))


def make_scan_mesh(shards: int):
    """1-D ("data",) mesh over the first `shards` devices.

    The sharded scan executor (engine/sharded.py) partitions stacked
    ciphertext-block columns over this axis; unlike make_host_mesh it
    takes an explicit shard count so elastic re-planning
    (runtime/elastic.py:elastic_scan_plan) can shrink the mesh after a
    straggler exclusion without restarting the process.
    """
    return _device_mesh((shards,), ("data",))


def make_query_mesh(data: int, model: int):
    """2-D ("data", "model") mesh for sharded query execution.

    The data axis partitions ciphertext-block lanes (the PR-7 scan
    axis); the model axis partitions the k RNS limbs of every
    (nblocks, 2, k, n) batch, so NTT/pointwise ops run limb-local and
    only the key-switch digit all-gather crosses it (engine/sharded.py,
    core/bfv.py:kswitch_gathered).  Both axes shrink independently
    under elastic re-planning (runtime/elastic.py).
    """
    return _device_mesh((data, model), ("data", "model"))
