import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on 512 virtual devices and record memory/cost/collective analysis.

MUST be executed as its own process (the XLA_FLAGS line above runs
before any other import, including jax): `python -m repro.launch.dryrun`.

Per cell we persist a JSON record under results/dryrun/ with:
  bytes per device (memory_analysis), HLO flops/bytes (cost_analysis),
  collective bytes by op kind (parsed from the optimized HLO), wall
  compile time — everything benchmarks/roofline.py consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --arch nshedb --shape scan_33m
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _op_bytes(shape_str: str) -> int:
    """Sum byte sizes of every tensor literal in an HLO result/operand."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+(\S+)\(", s)
        if not m:
            continue
        op = m.group(2).rstrip("(").split(".")[0]
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                out[kind] += _op_bytes(m.group(1))
    return out


def _mesh(kind: str):
    from .mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


# ---------------------------------------------------------------------------
# Cell builders: return (fn, args_specs, in_shardings) ready to lower.
# ---------------------------------------------------------------------------

def build_lm_cell(arch: str, shape: str, mesh):
    import functools

    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config, input_specs
    from ..dist.sharding import cache_sharding, input_sharding, param_sharding
    from ..models import lm
    from ..train import steps as steps_mod
    from ..train.optim import adamw_init

    cfg = get_config(arch)
    specs = input_specs(cfg, shape, dtype=jnp.bfloat16)
    kind = specs["kind"]

    pshapes = jax.eval_shape(
        lambda k: lm.init_params(k, cfg, jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pshard = param_sharding(pshapes, mesh)
    batch_specs = {k: v for k, v in specs.items()
                   if k in ("tokens", "labels", "patches", "enc_embeds")}
    bshard = input_sharding(batch_specs, mesh)

    if kind == "train":
        oshapes = jax.eval_shape(adamw_init, pshapes)
        oshard = {"adam": param_sharding(oshapes, mesh)}
        oshapes = {"adam": oshapes}
        step = steps_mod.make_train_step(cfg)
        args = (pshapes, oshapes, batch_specs)
        shardings = (pshard, oshard, bshard)
        return step, args, shardings, (pshard, oshard, None)

    B = specs["batch"]

    def _logit_shard(shape):
        names = mesh.axis_names
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        ba = tuple(a for a in ("pod", "data") if a in names)
        nb = 1
        for a in ba:
            nb *= sizes[a]
        ba = (ba if len(ba) > 1 else (ba[0] if ba else None)) \
            if nb and shape[0] % max(nb, 1) == 0 else None
        v_ax = "model" if shape[-1] % sizes.get("model", 1) == 0 else None
        return NamedSharding(mesh, P(ba, v_ax))

    if kind == "prefill":
        step = steps_mod.make_prefill_step(cfg)
        args = (pshapes, batch_specs)
        # The returned KV caches are built inside the step; without
        # explicit out_shardings GSPMD under-shards them (perf iteration
        # #2: qwen2 prefill output was 20 GiB/device batch-only-sharded).
        out_shapes = jax.eval_shape(step, pshapes, batch_specs)
        out_sh = (_logit_shard(out_shapes[0].shape),
                  cache_sharding(out_shapes[1], mesh, B))
        return step, args, (pshard, bshard), out_sh

    # decode
    ctx = specs["cache_len"]
    cshapes = jax.eval_shape(
        functools.partial(lm.make_cache, cfg, B, ctx, jnp.bfloat16))
    cshard = cache_sharding(cshapes, mesh, B)
    base = steps_mod.make_decode_step(cfg)
    step = functools.partial(base, pos=ctx)
    args = (pshapes, cshapes, batch_specs)
    out_shapes = jax.eval_shape(step, pshapes, cshapes, batch_specs)
    out_sh = (_logit_shard(out_shapes[0].shape),
              cache_sharding(out_shapes[1], mesh, B))
    return step, args, (pshard, cshard, bshard), out_sh


def build_nshedb_cell(shape: str, mesh):
    import functools

    from jax.sharding import NamedSharding

    from ..configs.nshedb import CONFIG, SHAPES
    from . import nshedb_step as Q

    cfg = CONFIG
    cell = SHAPES[shape]
    nblocks = cell["nblocks"]
    specs = Q.input_specs(cfg, nblocks)
    shard = Q.shardings(mesh, cfg, nblocks)
    fn = functools.partial(Q.query_step, eq_levels=cfg.eq_levels,
                           rot_steps=cell.get("rot_steps", cfg.rot_steps),
                           ks_mode=cell.get("ks_mode"))
    names = list(specs)
    step = lambda *a: fn(**dict(zip(names, a)))
    args = tuple(specs[n] for n in names)
    shardings = tuple(shard[n] for n in names)
    return step, args, (shardings,)


# ---------------------------------------------------------------------------
# Runner.
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, mesh_kind: str, *, save: bool = True) -> dict:
    mesh = _mesh(mesh_kind)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "mesh_shape": list(mesh.devices.shape), "status": "ok"}
    t0 = time.time()
    try:
        out_sh = None
        if arch == "nshedb":
            step, args, shardings = build_nshedb_cell(shape, mesh)
            flat_shardings = shardings[0]
        else:
            step, args, shardings, out_sh = build_lm_cell(arch, shape, mesh)
            flat_shardings = shardings
        with mesh:
            kw = {"out_shardings": out_sh} if out_sh is not None else {}
            jitted = jax.jit(step, in_shardings=flat_shardings, **kw)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1.0)),
            hlo_bytes=float(cost.get("bytes accessed", -1.0)),
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            peak_bytes=int(getattr(mem, "peak_memory_in_bytes", 0) or
                           (getattr(mem, "argument_size_in_bytes", 0)
                            + getattr(mem, "temp_size_in_bytes", 0))),
            collective_bytes=coll,
            collective_total=sum(coll.values()),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fn = f"{arch}__{shape}__{mesh_kind}.json".replace("/", "_")
        with open(os.path.join(RESULTS_DIR, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from ..configs import ARCHS, shape_cells
    from ..configs.nshedb import SHAPES as NSHAPES

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCHS:
            for shape, skip in shape_cells(arch):
                if skip is None:
                    cells.append((arch, shape))
        for shape in NSHAPES:
            cells.append(("nshedb", shape))
    else:
        assert args.arch and args.shape, "--arch + --shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        for mk in meshes:
            fn = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mk}.json")
            if args.skip_existing and os.path.exists(fn):
                with open(fn) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"SKIP {arch} {shape} {mk} (cached)")
                        continue
            rec = run_cell(arch, shape, mk)
            msg = (f"{rec['status'].upper():4s} {arch:20s} {shape:12s} {mk:6s} "
                   f"compile={rec.get('compile_s', '-')}s")
            if rec["status"] == "ok":
                msg += (f" flops={rec['flops']:.3g}"
                        f" coll={rec['collective_total']:.3g}B"
                        f" peak={rec['peak_bytes']/2**30:.2f}GiB/dev")
            else:
                msg += f" err={rec['error'][:120]}"
            print(msg, flush=True)


if __name__ == "__main__":
    main()
