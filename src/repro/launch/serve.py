"""Serving driver: prefill + batched decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --smoke \
      --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models import lm
from ..train import steps as steps_mod
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    B, S = args.batch, args.prompt_len
    key = jax.random.PRNGKey(0)

    with mesh:
        params = lm.init_params(key, cfg, jnp.float32)
        prefill = steps_mod.make_prefill_step(cfg)
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
        if cfg.frontend == "vision":
            batch["patches"] = jax.random.normal(key, (B, 8, cfg.d_model))
        if cfg.is_enc_dec:
            batch["enc_embeds"] = jax.random.normal(key, (B, S // 4, cfg.d_model))

        t0 = time.perf_counter()
        logits, caches = jax.jit(prefill)(params, batch)
        print(f"prefill {B}x{S}: {time.perf_counter()-t0:.2f}s")

        decode = steps_mod.make_decode_step(cfg)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        toks = [tok]
        for i in range(args.gen):
            dbatch = {"tokens": tok}
            if cfg.is_enc_dec:
                dbatch["enc_embeds"] = batch["enc_embeds"]
            t0 = time.perf_counter()
            logits, caches = jax.jit(
                lambda p, c, b: decode(p, c, b, pos=S + i))(params, caches, dbatch)
            tok = jnp.argmax(logits, axis=-1)[:, None]
            toks.append(tok)
        out = jnp.concatenate(toks, axis=1)
        print("generated:", out[0].tolist())
        return out


if __name__ == "__main__":
    main()
