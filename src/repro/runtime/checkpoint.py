"""Fault-tolerant checkpointing.

Design (per DESIGN.md §6):
  * step-granular checkpoints: params + optimizer + data-pipeline cursor
  * atomic manifest: every leaf is written under a tmp directory, then a
    single os.rename publishes the step — a crash mid-write can never
    leave a readable-but-corrupt checkpoint
  * async double-buffered writer: the training loop hands off host
    copies and keeps stepping while the previous snapshot flushes
  * elastic restore: leaves are stored unsharded with their logical
    names; restore re-shards onto whatever mesh the new job brings up
    (different device count included) via NamedSharding placement
  * keep-last-k garbage collection
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from .faults import CheckpointCorruptFault


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[name] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, params, opt=None, extra: dict | None = None):
        """Snapshot to host then write (async by default)."""
        host = {
            "params": jax.tree.map(np.asarray, params),
            "opt": jax.tree.map(np.asarray, opt) if opt is not None else None,
        }
        meta = {"step": step, "extra": extra or {}}
        self.wait()                               # double buffer: one in flight
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": meta["step"], "extra": meta["extra"], "leaves": {}}
        for group in ("params", "opt"):
            tree = host[group]
            if tree is None:
                continue
            for name, leaf in _flatten(tree).items():
                arr = np.asarray(leaf)
                fn = f"{group}__{name.replace('/', '__')}.npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][f"{group}/{name}"] = {
                    "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                    # exact on-disk size: lets verify_step detect a leaf
                    # truncated *after* the atomic publish (at-rest rot)
                    "bytes": os.path.getsize(os.path.join(tmp, fn))}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify_step(self, step: int) -> bool:
        """Cheap integrity check of a published snapshot: manifest reads
        back and every leaf file exists at its recorded byte size.
        Catches truncation/deletion *after* the atomic publish, which
        the write-path atomicity can not protect against."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        for info in manifest.get("leaves", {}).values():
            path = os.path.join(d, info["file"])
            if not os.path.exists(path):
                return False
            if "bytes" in info and os.path.getsize(path) != info["bytes"]:
                return False
        return True

    def restore(self, step: int, params_like, opt_like=None, shardings=None):
        """Rebuild pytrees from a checkpoint.  params_like/opt_like give
        structure; shardings (optional, same structure) re-shard onto the
        *current* mesh — elastic restore onto any device count.  An
        unreadable manifest or leaf raises a typed
        CheckpointCorruptFault (runtime/faults.py)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruptFault(
                f"step {step}: manifest unreadable: {e}",
                stage="restore", detail={"step": step}) from e

        def rebuild(group, like, shard_tree):
            if like is None:
                return None
            names = list(_flatten(like))
            flat_like, treedef = jax.tree_util.tree_flatten(like)
            shards = (jax.tree_util.tree_flatten(shard_tree)[0]
                      if shard_tree is not None else [None] * len(flat_like))
            leaves = []
            for name, ref, sh in zip(names, flat_like, shards):
                info = manifest["leaves"][f"{group}/{name}"]
                try:
                    arr = np.load(os.path.join(d, info["file"]))
                except (OSError, ValueError, EOFError) as e:
                    raise CheckpointCorruptFault(
                        f"step {step}: leaf {group}/{name} unreadable: {e}",
                        stage="restore",
                        detail={"step": step, "leaf": f"{group}/{name}"}) from e
                if sh is not None:
                    leaves.append(jax.device_put(arr, sh))
                else:
                    leaves.append(jax.device_put(arr))
            return treedef.unflatten(leaves)

        params = rebuild("params", params_like,
                         shardings.get("params") if shardings else None)
        opt = rebuild("opt", opt_like,
                      shardings.get("opt") if shardings else None)
        return params, opt, manifest["extra"]

    def restore_latest_valid(self, params_like, opt_like=None, shardings=None):
        """Restore the newest *intact* snapshot, walking backward past
        corrupt ones (truncated leaves, unreadable manifests — the
        at-rest failures verify_step detects).  Returns
        (step, params, opt, extra); raises CheckpointCorruptFault when
        no intact snapshot remains."""
        skipped = []
        for step in reversed(self.all_steps()):
            if not self.verify_step(step):
                skipped.append(step)
                continue
            try:
                params, opt, extra = self.restore(
                    step, params_like, opt_like, shardings)
            except CheckpointCorruptFault:
                skipped.append(step)
                continue
            return step, params, opt, extra
        raise CheckpointCorruptFault(
            f"no intact checkpoint under {self.dir} "
            f"(skipped corrupt steps {skipped})",
            stage="restore", detail={"skipped": skipped})
