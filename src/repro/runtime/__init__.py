"""Runtime substrate: fault-tolerant checkpointing, elastic resharding,
straggler detection."""
from .checkpoint import CheckpointManager  # noqa: F401
from .elastic import StragglerDetector, elastic_mesh_plan  # noqa: F401
