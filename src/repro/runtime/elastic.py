"""Straggler detection and elastic mesh planning.

StragglerDetector consumes per-worker step-time reports (heartbeats) and
maintains an EWMA per worker; a worker slower than `threshold` x the
fleet median for `patience` consecutive heartbeats — or silent past the
timeout — lands on the exclusion list.  The launcher feeds the exclusion
list to elastic_mesh_plan() on restart to pick the largest viable mesh
from the surviving devices, and CheckpointManager.restore() re-shards
the last snapshot onto it (leaves are stored unsharded, so any device
count works).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class WorkerStat:
    ewma: float = 0.0
    last_seen: float = 0.0
    strikes: int = 0
    reports: int = 0    # heartbeats received
    judged: int = 0     # heartbeats already consumed by evaluate()


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, patience: int = 3,
                 timeout_s: float = 60.0, alpha: float = 0.3):
        self.threshold = threshold
        self.patience = patience
        self.timeout_s = timeout_s
        self.alpha = alpha
        self.workers: dict[int, WorkerStat] = {}

    def report(self, worker: int, step_time: float, now: float | None = None):
        now = time.monotonic() if now is None else now
        st = self.workers.setdefault(worker, WorkerStat())
        st.ewma = step_time if st.ewma == 0 else \
            self.alpha * step_time + (1 - self.alpha) * st.ewma
        st.last_seen = now
        st.reports += 1

    def _median(self) -> float:
        vals = sorted(w.ewma for w in self.workers.values() if w.ewma > 0)
        return vals[len(vals) // 2] if vals else 0.0

    def evaluate(self, now: float | None = None) -> list[int]:
        """Returns the exclusion list (dead or persistently slow).

        Idempotent over a heartbeat window: strikes advance only for
        workers with reports not yet judged, so calling evaluate()
        repeatedly between heartbeats never double-counts a window
        toward `patience`.  The deadness check stays unconditional — a
        silent worker has no new reports by definition.
        """
        now = time.monotonic() if now is None else now
        med = self._median()
        out = []
        for wid, st in self.workers.items():
            dead = now - st.last_seen > self.timeout_s
            if st.reports > st.judged:
                slow = med > 0 and st.ewma > self.threshold * med
                st.strikes = st.strikes + 1 if slow else 0
                st.judged = st.reports
            if dead or st.strikes >= self.patience:
                out.append(wid)
        return sorted(out)

    def reset(self, worker: int) -> None:
        """Readmission: forget a worker's history entirely (it returns
        as a blank slate after replacement/repair — stale EWMA from its
        degraded era must not bias the new incarnation)."""
        self.workers.pop(worker, None)


def elastic_mesh_plan(total_devices: int, excluded: int,
                      model_parallel: int = 16) -> dict:
    """Pick the largest (data, model) mesh from surviving devices.

    model_parallel is kept fixed (TP size is baked into layouts and must
    divide head/expert counts); the data axis absorbs the loss — the
    standard elasticity policy for TP x FSDP jobs.
    """
    alive = total_devices - excluded
    if alive < model_parallel:
        raise RuntimeError(f"only {alive} devices left, need >= {model_parallel} for TP")
    data = alive // model_parallel
    # largest power-of-two data axis keeps batch divisibility
    d = 1
    while d * 2 <= data:
        d *= 2
    used = d * model_parallel
    return {"mesh_shape": (d, model_parallel), "axes": ("data", "model"),
            "devices_used": used, "devices_idle": alive - used,
            "global_batch_scale": d}


def elastic_scan_plan(shards: int, excluded) -> dict:
    """Re-shard plan for the 1-D sharded scan mesh after exclusions.

    The scan path shards ciphertext blocks over a pure data axis, so
    unlike elastic_mesh_plan there is no TP constraint — any surviving
    power-of-two worker count is viable (power of two keeps the padded
    nblocks divisibility stable across re-shards).
    """
    dropped = set(excluded)
    alive = [w for w in range(shards) if w not in dropped]
    if not alive:
        raise RuntimeError("all scan shard workers excluded")
    d = 1
    while d * 2 <= len(alive):
        d *= 2
    return {"shards": d, "workers": alive[:d], "axes": ("data",),
            "workers_idle": len(alive) - d, "excluded": sorted(dropped)}


def elastic_limb_plan(limb_shards: int, excluded, limbs: int | None = None) -> dict:
    """Re-shard plan for the model (RNS-limb) axis after exclusions.

    Unlike the data axis there is no power-of-two constraint: the limb
    padding rule (limb_pad_to in engine/sharded.py) absorbs any survivor
    count M' by padding k up to the next multiple of M', so every
    non-empty survivor set is viable and no worker idles.
    """
    dropped = set(excluded)
    alive = [m for m in range(limb_shards) if m not in dropped]
    if not alive:
        raise RuntimeError("all limb shard workers excluded")
    plan = {"limb_shards": len(alive), "workers": alive, "axes": ("model",),
            "excluded": sorted(dropped)}
    if limbs is not None:
        m = len(alive)
        plan["limb_pad"] = (m - limbs % m) % m
    return plan
