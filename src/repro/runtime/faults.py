"""Deterministic fault injection + the typed execution-fault taxonomy.

NSHEDB's correctness hinges on the planner's noise predictions holding
at runtime: one under-predicted level means silent garbage at decrypt,
a device lost mid-`sharded_fold` kills the query, a poisoned cache
entry corrupts every consumer.  This module gives the engine (a) a
typed fault vocabulary so every failure is *reported*, never silent,
and (b) a deterministic injection harness so the chaos suite can force
each failure class and assert the recovery contract of DESIGN.md §9:

    every injected fault ends in either a byte-identical result or a
    typed ExecutionFault — zero silent wrong answers.

Injection is scoped, not ambient: `with inject(FaultPlan(...)):` arms
the hooks; outside the context every hook is a cheap no-op, so the
production path pays one attribute read per guard site.  A FaultPlan is
deterministic by construction — faults fire on fixed call counts, never
on randomness or wall-clock — which is what lets the CI chaos lane run
the same matrix on every commit.

Fault classes (see DESIGN.md §9 for the recovery contract of each):

  overflow            noise-model under-prediction -> decrypt garbage.
                      Injected by wrapping `bk.model` in an
                      UnderReportingNoiseModel (core/noise.py) that
                      hides mul growth; detected by the decrypt-boundary
                      headroom guard (`check_decrypt`) and the
                      plaintext sentinel lane (`SentinelLane`).
  device-loss         a shard worker dies mid-stage.  Injected by
                      `maybe_device_loss(stage)` hooks at executor
                      stage boundaries and inside the block fold;
                      recovered by reshard + stage-checkpoint resume.
  straggler           a worker runs slow without dying.  Injected as a
                      per-worker slowdown factor applied to the
                      synthetic heartbeats the executor derives from
                      the shard cost ledger; handled by
                      StragglerDetector exclusion + reshard.
  cache-poison        a WorkloadCache entry's ciphertext is corrupted
                      at rest.  Injected by `poison_cache`; detected by
                      content fingerprints at serve time.
  checkpoint-corrupt  a snapshot is truncated after publish.  Injected
                      by `truncate_checkpoint`; handled by
                      CheckpointManager.restore_latest_valid falling
                      back to the previous intact snapshot.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import zlib

import numpy as np


# ---------------------------------------------------------------------------
# Typed faults.
# ---------------------------------------------------------------------------

class ExecutionFault(RuntimeError):
    """Base of every typed runtime failure.  Carrying the query, stage
    and worker makes chaos-matrix assertions and operator triage
    possible without parsing messages."""

    kind = "fault"

    def __init__(self, message: str, *, query: str = "", stage: str = "",
                 worker: int | None = None, detail: dict | None = None):
        super().__init__(message)
        self.query = query
        self.stage = stage
        self.worker = worker
        self.detail = detail or {}


class NoiseOverflowFault(ExecutionFault):
    """Noise budget exhausted (or about to be) at a decrypt boundary —
    the result can not be trusted.  Raised only after bounded recovery
    (refresh-and-retry, then re-derive) failed."""

    kind = "overflow"


class DeviceLossFault(ExecutionFault):
    """A shard worker vanished mid-execution.  Recoverable while a
    viable (power-of-two) survivor mesh remains."""

    kind = "device-loss"


class StragglerFault(ExecutionFault):
    """Straggler exclusion left no viable scan mesh."""

    kind = "straggler"


class CachePoisonFault(ExecutionFault):
    """A served WorkloadCache entry failed its content fingerprint
    (strict-integrity mode; the default policy silently drops and
    re-derives instead)."""

    kind = "cache-poison"


class CheckpointCorruptFault(ExecutionFault):
    """A checkpoint snapshot is unreadable/truncated and no intact
    fallback exists (restore_latest_valid exhausts older snapshots
    before raising)."""

    kind = "checkpoint-corrupt"


# ---------------------------------------------------------------------------
# The injection plan.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultPlan:
    """One deterministic injection schedule.

    Counters are *consumed* as faults fire (a plan with
    ``device_loss_count=1`` loses a device exactly once, so the retry
    succeeds); ``events`` logs every fired fault for test assertions.
    """

    # overflow: hide `underpredict_bits` of noise growth from the model
    # on `underpredict_count` ct-ct multiplies, skipping the first
    # `underpredict_after` calls of each execution attempt.
    underpredict_bits: float = 0.0
    underpredict_count: int = 0
    underpredict_after: int = 0

    # device loss: raise DeviceLossFault when execution enters `stage`
    # ("atoms"/"where"/"aux:<name>"/"gmasks"/"aggregate"/"fold", or
    # "any"), `count` times in total.
    device_loss_stage: str | None = None
    device_loss_worker: int = 0
    device_loss_count: int = 1

    # straggler: per-worker heartbeat slowdown factors, e.g. {3: 10.0}.
    straggler_slowdown: dict = dataclasses.field(default_factory=dict)

    events: list = dataclasses.field(default_factory=list)

    def log(self, kind: str, **detail) -> None:
        self.events.append({"kind": kind, **detail})

    def fired(self, kind: str) -> int:
        return sum(1 for e in self.events if e["kind"] == kind)


_ACTIVE: FaultPlan | None = None


def active() -> FaultPlan | None:
    return _ACTIVE


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Arm `plan` for the duration of the with-block (not reentrant —
    one chaos scenario at a time keeps the schedule deterministic)."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("fault injection is already active")
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None


# ---------------------------------------------------------------------------
# Hooks the engine calls (each is a no-op when nothing is armed).
# ---------------------------------------------------------------------------

def maybe_device_loss(stage: str) -> None:
    """Raise an injected DeviceLossFault when the armed plan targets
    this stage.  Called at executor stage boundaries and at the top of
    both backends' `fold_blocks` (the mid-`sharded_fold` case)."""
    p = _ACTIVE
    if p is None or p.device_loss_stage is None or p.device_loss_count <= 0:
        return
    if p.device_loss_stage != "any" and p.device_loss_stage != stage:
        return
    p.device_loss_count -= 1
    p.log("device-loss", stage=stage, worker=p.device_loss_worker)
    raise DeviceLossFault(
        f"injected device loss: worker {p.device_loss_worker} lost during "
        f"stage '{stage}'", stage=stage, worker=p.device_loss_worker)


@contextlib.contextmanager
def tampered_noise_model(bk):
    """Install an UnderReportingNoiseModel on `bk` for one execution
    attempt when the armed plan schedules noise under-prediction.

    The tampered-call budget lives on the *plan*, so a recovery retry
    does not re-arm an already-exhausted injection — exactly the
    transient-mispredict scenario the refresh-and-retry arm targets.
    """
    p = _ACTIVE
    if p is None or p.underpredict_count <= 0 or p.underpredict_bits <= 0:
        yield None
        return
    from ..core.noise import UnderReportingNoiseModel

    def take() -> bool:
        if p.underpredict_count <= 0:
            return False
        p.underpredict_count -= 1
        p.log("underpredict", bits=p.underpredict_bits)
        return True

    wrapper = UnderReportingNoiseModel(bk.model, p.underpredict_bits,
                                       skip=p.underpredict_after, take=take)
    bk.model = wrapper
    try:
        yield wrapper
    finally:
        bk.model = wrapper.inner


def hidden_noise_bits(bk) -> float:
    """Noise growth the backend's model failed to account for (nonzero
    only under an armed under-prediction injection)."""
    return float(getattr(bk.model, "hidden_bits", 0.0))


def check_decrypt(bk, ct, *, query: str = "", stage: str = "decrypt",
                  headroom_bits: float = 0.0) -> None:
    """Decrypt-boundary headroom guard.

    The worst lane's remaining budget, minus any growth the model is
    known to be hiding, must clear `headroom_bits` — otherwise the
    plaintext under this ciphertext can not be trusted and the caller
    must recover (refresh-and-retry / re-derive) instead of decrypting
    garbage.
    """
    noise = getattr(ct, "noise", None)
    if noise is None:
        return
    b = float(np.min(np.asarray(bk.model.budget(noise))))
    hidden = hidden_noise_bits(bk)
    if b - hidden <= headroom_bits:
        raise NoiseOverflowFault(
            f"{query or '<query>'}: headroom check failed at {stage}: "
            f"budget {b:.1f} bits - {hidden:.1f} hidden <= "
            f"headroom {headroom_bits:.1f}",
            query=query, stage=stage,
            detail={"budget_bits": b, "hidden_bits": hidden})


class SentinelLane:
    """Plaintext-sentinel canary for one guarded execution.

    A known-plaintext ciphertext is squared to the run's observed
    multiplicative depth with auto-refresh disabled: if the engine's
    real depth does not fit the budget, the sentinel either exhausts
    (backend raises) or decodes wrong — both surface as a typed
    NoiseOverflowFault *before* any query result is trusted.  All
    sentinel ops run outside the accounting: OpStats are snapshot and
    restored so plan-model validation never sees the canary.
    """

    def __init__(self, bk, value: int = 2):
        self.bk = bk
        self.ct = None
        self.expected = int(value) % bk.t
        self.depth = 0

    def verify(self, depth: int, query: str = "") -> None:
        bk = self.bk
        snap = bk.stats.clone()
        prev_auto, prev_ctx = bk.auto_refresh, bk.shard_ctx
        bk.auto_refresh = False
        bk.shard_ctx = None
        try:
            if self.ct is None:
                self.ct = bk.encrypt(
                    np.full(bk.slots, self.expected, dtype=np.int64))
            while self.depth < depth:
                self.ct = bk.mul(self.ct, self.ct)
                self.expected = (self.expected * self.expected) % bk.t
                self.depth += 1
            got = int(bk.decrypt(self.ct)[0])
        except RuntimeError as e:
            if isinstance(e, ExecutionFault):
                raise
            raise NoiseOverflowFault(
                f"{query or '<query>'}: sentinel lane exhausted at depth "
                f"{self.depth}/{depth}: {e}",
                query=query, stage="sentinel") from e
        finally:
            for f in dataclasses.fields(type(snap)):
                setattr(bk.stats, f.name, getattr(snap, f.name))
            bk.auto_refresh, bk.shard_ctx = prev_auto, prev_ctx
        if got != self.expected:
            raise NoiseOverflowFault(
                f"{query or '<query>'}: sentinel decoded {got}, expected "
                f"{self.expected} at depth {self.depth} — launch noise "
                f"exceeded the model", query=query, stage="sentinel",
                detail={"depth": self.depth})


# ---------------------------------------------------------------------------
# State-corruption injectors (one-shot helpers, still logged on the plan).
# ---------------------------------------------------------------------------

def poison_cache(cache, bk, entries: int | None = 1) -> list:
    """Corrupt the ciphertext content of the first `entries` atom
    entries of a WorkloadCache in place (None = all).  Only mock
    ciphertext handles expose their content for deterministic
    tampering; the BFV handles are opaque by design."""
    keys = list(cache.entries)
    keys = keys if entries is None else keys[:entries]
    for key in keys:
        for b in cache.entries[key].blocks:
            if not hasattr(b, "vec"):
                raise NotImplementedError(
                    "poison_cache tampers mock ciphertext handles only")
            b.vec = (b.vec + 1) % bk.t
    if _ACTIVE is not None:
        _ACTIVE.log("cache-poison", entries=len(keys))
    return keys


def truncate_checkpoint(directory: str, step: int, keep_bytes: int = 16) -> str:
    """Truncate the first leaf file of a published snapshot — the
    classic partially-written-at-rest corruption (disk full, torn
    copy).  Returns the truncated file path."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    name = sorted(manifest["leaves"])[0]
    path = os.path.join(d, manifest["leaves"][name]["file"])
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)
    if _ACTIVE is not None:
        _ACTIVE.log("checkpoint-truncate", step=step, leaf=name)
    return path


def fingerprint_blocks(bk, blocks) -> list | None:
    """Content fingerprints for a list of ciphertext handles, or None
    when the backend's handles are opaque (real BFV: refresh re-encrypts
    the payload, so no stable content hash exists)."""
    fp = getattr(bk, "fingerprint", None)
    if fp is None:
        return None
    out = []
    for b in blocks:
        h = fp(b)
        if h is None:
            return None
        out.append(h)
    return out


def crc_array(arr) -> int:
    """Stable content hash of a numpy payload (shape included, so a
    reshape never collides with its flat twin)."""
    a = np.ascontiguousarray(arr)
    return zlib.crc32(repr(a.shape).encode() + a.tobytes())
