"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel directory ships three files:
  <name>.py   the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py      the jit'd public wrapper (interpret=True on CPU)
  ref.py      the pure-jnp oracle the tests assert against

Hardware adaptation (see DESIGN.md §3): Pallas TPU has no 64-bit integer
ALU, so all modular arithmetic uses uint32 lanes with 16-bit limb
splitting — Shoup multiplication for known twiddles (NTT) and Barrett
reduction for ciphertext-ciphertext products (modops).  The MXU is
float-only; the NTT stays on the VPU with exact integer ops.

Kernels:
  ntt            negacyclic NTT, whole polynomial VMEM-resident, radix-2
                 stages in-kernel, grid over (batch x limb)
  modops         dyadic (pointwise) ciphertext ops: Barrett modmul/add/sub
  rotate_reduce  log-depth packed aggregation (the paper's rotate+add sum)
  flash_attn     blocked online-softmax attention for the LM substrate
                 (causal / local-window / logit-softcap variants)

Batched evaluation path
-----------------------
The BFV core consumes the NTT and modops kernels through
`core/limbops.LimbOps`, a dispatch layer that accepts (..., k, n)
arrays — a whole column of ciphertext blocks at once — and flattens the
batch into the kernels' (rows, n) grid, tiling the per-limb twiddle and
modulus tables to match.  The `backend` flag on `BFVContext` /
`BFVBackend(kernel_backend=...)` selects "pallas" vs the "ref" jnp
oracles ("auto" picks Pallas on TPU); pass `interpret=True` (the default
off-TPU) to run the kernels through the Pallas interpreter on CPU.  Both
paths are exact and bit-identical, verified by tests/test_limbops_parity
and tests/test_batched_equivalence.  `MockBackend(kernel_reduce=True)`
likewise routes its `sum_slots` data movement through the rotate_reduce
kernel while charging the looped schedule's op counts.
"""
