"""Public wrapper for the NTT kernel: int64 (k, n) limb layout in/out,
Shoup tables built once per parameter set and cached."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...core.params import NttTables
from ..u32 import shoup_precompute
from .ntt import ntt_fwd_pallas, ntt_inv_pallas


_CACHE: dict[tuple[int, bool], tuple] = {}


def shoup_tables(tables: NttTables, inverse: bool = False):
    """uint32 twiddle + Shoup-companion arrays for a parameter base."""
    key = (id(tables), inverse)
    if key in _CACHE:
        return _CACHE[key]
    psi = np.asarray(tables.ipsi_rev if inverse else tables.psi_rev, dtype=np.uint64)
    q = np.asarray(tables.q, dtype=np.uint64)
    shoup = (psi << np.uint64(32)) // q[:, None]
    out = (jnp.asarray(psi.astype(np.uint32)),
           jnp.asarray(shoup.astype(np.uint32)),
           jnp.asarray(q.astype(np.uint32))[:, None])
    if inverse:
        ninv = np.asarray(tables.n_inv, dtype=np.uint64)
        ninv_shoup = (ninv << np.uint64(32)) // q
        out = out + (jnp.asarray(ninv.astype(np.uint32))[:, None],
                     jnp.asarray(ninv_shoup.astype(np.uint32))[:, None])
    _CACHE[key] = out
    return out


def ntt_fwd(a_i64, tables: NttTables, *, interpret: bool = True):
    """Forward NTT of (k, n) int64 limbs via the Pallas kernel."""
    psi, shoup, q = shoup_tables(tables, inverse=False)
    a = a_i64.astype(jnp.uint32)
    out = ntt_fwd_pallas(a, psi, shoup, q, interpret=interpret)
    return out.astype(jnp.int64)


def ntt_inv(a_i64, tables: NttTables, *, interpret: bool = True):
    ipsi, ishoup, q, ninv, ninv_shoup = shoup_tables(tables, inverse=True)
    a = a_i64.astype(jnp.uint32)
    out = ntt_inv_pallas(a, ipsi, ishoup, q, ninv, ninv_shoup, interpret=interpret)
    return out.astype(jnp.int64)
