"""Pure-jnp oracle for the NTT kernel: core/ntt.py's int64 reference."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.ntt import intt_ref, ntt_ref  # noqa: F401


def ntt_fwd_ref(a_i64, psi_rev_i64, q_i64):
    """(k, n) int64 forward negacyclic NTT (exact 60-bit products)."""
    return ntt_ref(a_i64, psi_rev_i64, q_i64)


def ntt_inv_ref(a_i64, ipsi_rev_i64, n_inv_i64, q_i64):
    return intt_ref(a_i64, ipsi_rev_i64, n_inv_i64, q_i64)
