"""Negacyclic NTT Pallas kernel.

One grid step transforms one (row = batch x limb) polynomial held
entirely in VMEM: n=32,768 coefficients x 4 B = 128 KiB per operand row —
comfortably VMEM-resident, so all log2(n) radix-2 stages run in-register
with zero HBM round-trips between stages (the key TPU adaptation: SEAL's
cache-blocked CPU NTT becomes a VMEM-resident VPU NTT).

Twiddles use Shoup precomputation (w' = floor(w*2^32/q)): one mulhi +
one wrapping mul-sub per butterfly — no 64-bit arithmetic.

Layout (matches core/ntt.py): forward = Cooley-Tukey with premultiplied
psi powers in bit-reversed order, output bit-reversed; inverse =
Gentleman-Sande consuming that order.  Pointwise products round-trip
without bit-reversal passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import u32


def _fwd_kernel(a_ref, psi_ref, psis_ref, q_ref, o_ref, *, log_n: int):
    """Forward NTT for one row.  a_ref: (1, n) uint32."""
    n = 1 << log_n
    a = a_ref[0, :]
    psi = psi_ref[0, :]
    psis = psis_ref[0, :]
    q = q_ref[0, 0]
    for s in range(log_n):
        m = 1 << s
        t_len = n >> (s + 1)
        ar = a.reshape(m, 2, t_len)
        w = psi[m:2 * m]          # static slice: m is a Python int here
        ws = psis[m:2 * m]
        U = ar[:, 0, :]
        V = u32.shoup_mulmod(ar[:, 1, :], w[:, None], ws[:, None], q)
        a = jnp.stack([u32.add_mod(U, V, q), u32.sub_mod(U, V, q)], axis=1).reshape(n)
    o_ref[0, :] = a


def _inv_kernel(a_ref, ipsi_ref, ipsis_ref, q_ref, ninv_ref, ninvs_ref, o_ref,
                *, log_n: int):
    """Inverse NTT (Gentleman-Sande) for one row."""
    n = 1 << log_n
    a = a_ref[0, :]
    ipsi = ipsi_ref[0, :]
    ipsis = ipsis_ref[0, :]
    q = q_ref[0, 0]
    for s in range(log_n):
        h = n >> (s + 1)
        ar = a.reshape(h, 2, 1 << s)
        w = ipsi[h:2 * h]
        ws = ipsis[h:2 * h]
        U = ar[:, 0, :]
        V = ar[:, 1, :]
        lo = u32.add_mod(U, V, q)
        hi = u32.shoup_mulmod(u32.sub_mod(U, V, q), w[:, None], ws[:, None], q)
        a = jnp.stack([lo, hi], axis=1).reshape(n)
    o_ref[0, :] = u32.shoup_mulmod(a, ninv_ref[0, 0], ninvs_ref[0, 0], q)


def ntt_fwd_pallas(a, psi, psi_shoup, q, *, interpret: bool = True):
    """a: (rows, n) uint32; psi/psi_shoup: (rows, n); q: (rows, 1).

    Grid over rows — each grid step keeps its whole polynomial in VMEM.
    """
    rows, n = a.shape
    log_n = n.bit_length() - 1
    kern = functools.partial(_fwd_kernel, log_n=log_n)
    row = lambda i: (i, 0)
    return pl.pallas_call(
        kern,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_specs=pl.BlockSpec((1, n), row),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32),
        interpret=interpret,
    )(a, psi, psi_shoup, q)


def ntt_inv_pallas(a, ipsi, ipsi_shoup, q, ninv, ninv_shoup, *, interpret: bool = True):
    rows, n = a.shape
    log_n = n.bit_length() - 1
    kern = functools.partial(_inv_kernel, log_n=log_n)
    row = lambda i: (i, 0)
    return pl.pallas_call(
        kern,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_specs=pl.BlockSpec((1, n), row),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32),
        interpret=interpret,
    )(a, ipsi, ipsi_shoup, q, ninv, ninv_shoup)
