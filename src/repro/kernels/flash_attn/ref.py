"""Oracle: dense softmax attention with the same masking variants."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None, sm_scale: float | None = None):
    """q: (bh, sq, d); k, v: (bh, sk, d)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(mask[None], p, 0.0)
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bqk,bkd->bqd", p / denom, v.astype(jnp.float32))
    return out.astype(q.dtype)
