"""Public wrapper: multi-head attention with GQA handling.

On TPU (interpret=False) this is the production attention for train /
prefill.  The CPU dry-run and the models' default path use ref.py's dense
attention; smoke tests run this wrapper in interpret mode to prove the
kernel integrates.
"""
from __future__ import annotations

import jax.numpy as jnp

from .flash_attn import flash_attention


def mha(q, k, v, *, causal: bool = True, window: int | None = None,
        softcap: float | None = None, interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with H % Hkv == 0."""
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, -1, D)
    vf = v.reshape(B * H, -1, D)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          softcap=softcap, interpret=interpret)
    return out.reshape(B, H, Sq, D)
