"""Blocked online-softmax attention (flash attention) Pallas kernel.

Used by the LM substrate for train/prefill.  Supports the variants the
assigned architectures need:
  causal        decoder self-attention
  window        local (sliding-window) attention — gemma2/3, recurrentgemma
  softcap       tanh logit soft-capping — gemma2 (50.0)

Tiling: grid (batch*heads, q_tiles, kv_tiles); Q tile (BLK_Q, d) stays
resident while K/V tiles stream; running max m, denominator l and the
accumulator live in VMEM scratch.  MXU-aligned tiles: BLK=128 by default.

The kv grid axis is innermost so the scratch carries across kv steps of
one q tile (Pallas guarantees sequential grid order on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                 sm_scale: float, causal: bool, window: int | None,
                 softcap: float | None, blk_q: int, blk_k: int, nk: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, :, :].astype(jnp.float32)           # (blk_q, d)
    k = k_ref[0, :, :].astype(jnp.float32)           # (blk_k, d)
    v = v_ref[0, :, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_i * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = kv_i * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]                               # (blk_q, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    # guard fully-masked rows (all NEG_INF): keep exp() finite
    p = jnp.exp(s - m_cur)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_cur

    @pl.when(kv_i == nk - 1)
    def _done():
        denom = jnp.where(l_sc[...] == 0.0, 1.0, l_sc[...])
        o_ref[0, :, :] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, sm_scale: float | None = None,
                    blk_q: int = 128, blk_k: int = 128, interpret: bool = True):
    """q: (bh, sq, d); k, v: (bh, sk, d) — heads pre-flattened into batch.

    GQA is handled by the caller repeating KV heads (or flattening the
    group axis into batch); d and the sequence tiles are MXU-aligned.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, "pad sequences to tile size"
    nq, nk = sq // blk_q, sk // blk_k
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kern = functools.partial(
        _attn_kernel, sm_scale=sm_scale, causal=causal, window=window,
        softcap=softcap, blk_q=blk_q, blk_k=blk_k, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu_scratch((blk_q, 1)),
            pltpu_scratch((blk_q, 1)),
            pltpu_scratch((blk_q, d)),
        ],
        interpret=interpret,
    )(q, k, v)


def pltpu_scratch(shape):
    """VMEM f32 scratch allocation (portable across pallas versions)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:
        return pl.ANY(shape, jnp.float32)  # interpret fallback
