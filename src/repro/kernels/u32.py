"""Exact modular arithmetic on uint32 lanes (shared by the HE kernels).

TPU has no 64-bit integer ALU, so 30-bit-prime RNS arithmetic is built
from 16-bit limb splitting on uint32 vectors:

  mulhi_u32      high 32 bits of a 32x32 product (4 partials + carries)
  shoup_mulmod   a * w mod q with w' = floor(w * 2^32 / q) precomputed —
                 one mulhi + one wrapping mul-sub (twiddles, plaintexts)
  barrett_mulmod general a * b mod q for q in (2^28, 2^30): full 60-bit
                 product in (hi, lo) halves, quotient via mu = 2^60 / q

All functions are shape-polymorphic jnp code: they run identically inside
Pallas kernel bodies and in host-side tests.
"""
from __future__ import annotations

import jax.numpy as jnp



def mulhi_u32(a, b):
    """High 32 bits of the 64-bit product of two uint32 vectors."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    a1, a0 = a >> 16, a & 0xFFFF
    b1, b0 = b >> 16, b & 0xFFFF
    lo = a0 * b0
    mid1 = a1 * b0
    mid2 = a0 * b1
    t = (lo >> 16) + (mid1 & 0xFFFF) + (mid2 & 0xFFFF)       # < 3 * 2^16
    return a1 * b1 + (mid1 >> 16) + (mid2 >> 16) + (t >> 16)


def mullo_u32(a, b):
    """Low 32 bits (uint32 multiply wraps — this is just `*`)."""
    return a.astype(jnp.uint32) * b.astype(jnp.uint32)


def shoup_precompute(w: int, q: int) -> int:
    """w' = floor(w * 2^32 / q) — host-side Python int math."""
    return (int(w) << 32) // int(q)


def shoup_mulmod(a, w, w_shoup, q):
    """a * w mod q with precomputed w' (Longa–Naehrig).  Result < q."""
    a = a.astype(jnp.uint32)
    hi = mulhi_u32(a, w_shoup)
    r = mullo_u32(a, w) - mullo_u32(hi, q)          # in [0, 2q)
    return jnp.where(r >= q, r - q, r)


def barrett_precompute(q: int) -> int:
    """mu = floor(2^60 / q); q in (2^28, 2^30) keeps mu < 2^32."""
    assert (1 << 28) < q < (1 << 30), f"Barrett tuned for 29/30-bit q, got {q}"
    return (1 << 60) // int(q)


def barrett_mulmod(a, b, q, mu):
    """General a*b mod q (a, b < q < 2^30) on uint32 lanes.

    P = a*b < 2^60 held as (hi, lo); x1 = floor(P / 2^29) < 2^31;
    qhat = floor(x1 * mu / 2^31); r = P - qhat*q in [0, 3q) -> 2 csubs.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    lo = mullo_u32(a, b)
    hi = mulhi_u32(a, b)                              # < 2^28
    x1 = (hi << 3) | (lo >> 29)                       # floor(P / 2^29)
    qhat = (mulhi_u32(x1, mu) << 1) | (mullo_u32(x1, mu) >> 31)
    r = lo - mullo_u32(qhat, q)                       # exact in low 32 bits
    r = jnp.where(r >= q, r - q, r)
    r = jnp.where(r >= q, r - q, r)
    return r


def add_mod(a, b, q):
    s = a.astype(jnp.uint32) + b.astype(jnp.uint32)   # < 2q < 2^31
    return jnp.where(s >= q, s - q, s)


def sub_mod(a, b, q):
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    return jnp.where(a >= b, a - b, a + q - b)
