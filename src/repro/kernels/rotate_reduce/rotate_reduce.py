"""Rotate-and-add reduction Pallas kernel (paper §4.2.2 COUNT/SUM).

The packed-aggregation doubling pattern — rotate by 1, 2, 4, ... and add
— executed entirely in VMEM for a batch of plaintext-domain rows.  On the
HE path the rotation is a Galois automorphism (core/bfv.py); this kernel
is the slot-domain equivalent used by the serving-side post-processing
and demonstrates the log-depth schedule the engine charges for.

Grid over rows; each row (n x 4 B = 128 KiB at n=32,768) stays resident
across all log2(n) stages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, t_ref, o_ref, *, log_n: int, stop_log: int):
    x = x_ref[0, :]
    t = t_ref[0, 0]
    for s in range(stop_log):
        x = (x + jnp.roll(x, -(1 << s))) % t
    o_ref[0, :] = x


def rotate_reduce_pallas(x, t, *, chunk: int | None = None, interpret: bool = True):
    """x: (rows, n) int32 values mod t; t: (rows, 1) int32.

    chunk=None reduces fully (every slot = row total); chunk=c stops at
    log2(c) stages — the exact-partial-sums mode (n/c partials per row).
    """
    rows, n = x.shape
    log_n = n.bit_length() - 1
    stop_log = log_n if chunk is None else (chunk.bit_length() - 1)
    kern = functools.partial(_kernel, log_n=log_n, stop_log=stop_log)
    row = lambda i: (i, 0)
    return pl.pallas_call(
        kern,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, n), row), pl.BlockSpec((1, 1), row)],
        out_specs=pl.BlockSpec((1, n), row),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x, t)
