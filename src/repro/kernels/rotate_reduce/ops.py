"""Public wrapper for the rotate-reduce kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .rotate_reduce import rotate_reduce_pallas


def rotate_reduce(x, t: int, chunk: int | None = None, *, interpret: bool = True):
    """x: (rows, n) integer array mod t -> reduced array, same shape."""
    x = jnp.asarray(x, dtype=jnp.int32)
    tv = jnp.full((x.shape[0], 1), t, dtype=jnp.int32)
    return rotate_reduce_pallas(x, tv, chunk=chunk, interpret=interpret)
