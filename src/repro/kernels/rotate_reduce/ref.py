"""Oracle for rotate_reduce: plain mod-t row sums / partial sums."""
from __future__ import annotations

import jax.numpy as jnp


def rotate_reduce_ref(x, t, chunk: int | None = None):
    """x: (rows, n) ints mod t.  Full reduce -> every slot = row sum;
    chunked -> slot i holds sum of its chunk's wrapped window."""
    rows, n = x.shape
    stop = n if chunk is None else chunk
    out = x
    s = 1
    while s < stop:
        out = (out + jnp.roll(out, -s, axis=1)) % t
        s *= 2
    return out
