"""Dyadic ciphertext-ciphertext Pallas kernels (Barrett uint32 path).

Pointwise modular multiply / add / sub over RNS limbs — the inner loop of
every BFV evaluation-domain operation (tensor products, key-switch digit
products, plaintext mask multiplies).

Tiling: grid over (limb, column tile).  Each step loads a (1, TILE)
stripe of both operands into VMEM — at TILE=32,768 that is 2 x 128 KiB in
+ 128 KiB out, far below VMEM, letting the compiler double-buffer HBM
streams while the VPU does the ~30-op Barrett sequence per lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import u32


def _mul_kernel(a_ref, b_ref, q_ref, mu_ref, o_ref):
    q = q_ref[0, 0]
    mu = mu_ref[0, 0]
    o_ref[...] = u32.barrett_mulmod(a_ref[...], b_ref[...], q, mu)


def _add_kernel(a_ref, b_ref, q_ref, o_ref):
    o_ref[...] = u32.add_mod(a_ref[...], b_ref[...], q_ref[0, 0])


def _sub_kernel(a_ref, b_ref, q_ref, o_ref):
    o_ref[...] = u32.sub_mod(a_ref[...], b_ref[...], q_ref[0, 0])


def _grid_specs(rows: int, n: int, tile: int):
    tiles = (n + tile - 1) // tile
    spec = pl.BlockSpec((1, tile), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return (rows, tiles), spec, scal


def mul_mod_pallas(a, b, q, mu, *, tile: int = 32768, interpret: bool = True):
    """a, b: (rows, n) uint32; q, mu: (rows, 1) uint32."""
    rows, n = a.shape
    tile = min(tile, n)
    grid, spec, scal = _grid_specs(rows, n, tile)
    return pl.pallas_call(
        _mul_kernel,
        grid=grid,
        in_specs=[spec, spec, scal, scal],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32),
        interpret=interpret,
    )(a, b, q, mu)


def add_mod_pallas(a, b, q, *, tile: int = 32768, interpret: bool = True):
    rows, n = a.shape
    tile = min(tile, n)
    grid, spec, scal = _grid_specs(rows, n, tile)
    return pl.pallas_call(
        _add_kernel, grid=grid, in_specs=[spec, spec, scal], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32), interpret=interpret,
    )(a, b, q)


def sub_mod_pallas(a, b, q, *, tile: int = 32768, interpret: bool = True):
    rows, n = a.shape
    tile = min(tile, n)
    grid, spec, scal = _grid_specs(rows, n, tile)
    return pl.pallas_call(
        _sub_kernel, grid=grid, in_specs=[spec, spec, scal], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32), interpret=interpret,
    )(a, b, q)
