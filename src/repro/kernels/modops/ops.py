"""Public wrappers: int64 limb layout in/out, Barrett constants cached."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..u32 import barrett_precompute
from .modops import add_mod_pallas, mul_mod_pallas, sub_mod_pallas

_MU: dict[tuple[int, ...], jnp.ndarray] = {}


def _mu_for(primes: tuple[int, ...]) -> jnp.ndarray:
    if primes not in _MU:
        _MU[primes] = jnp.asarray(
            np.array([barrett_precompute(q) for q in primes], dtype=np.uint32))[:, None]
    return _MU[primes]


def mul_mod(a_i64, b_i64, primes: tuple[int, ...], *, interpret: bool = True):
    q = jnp.asarray(np.array(primes, dtype=np.uint32))[:, None]
    out = mul_mod_pallas(a_i64.astype(jnp.uint32), b_i64.astype(jnp.uint32),
                         q, _mu_for(tuple(primes)), interpret=interpret)
    return out.astype(jnp.int64)


def add_mod(a_i64, b_i64, primes: tuple[int, ...], *, interpret: bool = True):
    q = jnp.asarray(np.array(primes, dtype=np.uint32))[:, None]
    return add_mod_pallas(a_i64.astype(jnp.uint32), b_i64.astype(jnp.uint32),
                          q, interpret=interpret).astype(jnp.int64)


def sub_mod(a_i64, b_i64, primes: tuple[int, ...], *, interpret: bool = True):
    q = jnp.asarray(np.array(primes, dtype=np.uint32))[:, None]
    return sub_mod_pallas(a_i64.astype(jnp.uint32), b_i64.astype(jnp.uint32),
                          q, interpret=interpret).astype(jnp.int64)
