"""Oracle: exact int64 pointwise modular arithmetic."""
from __future__ import annotations

import jax.numpy as jnp


def mul_mod_ref(a_i64, b_i64, q_i64):
    """(rows, n) x (rows, n) mod q[rows]; products < 2^60, exact int64."""
    return (a_i64 * b_i64) % q_i64[:, None]


def add_mod_ref(a_i64, b_i64, q_i64):
    return (a_i64 + b_i64) % q_i64[:, None]


def sub_mod_ref(a_i64, b_i64, q_i64):
    return (a_i64 - b_i64) % q_i64[:, None]
