"""AdamW, hand-rolled (no optax in the environment).

Optimizer state is a pytree congruent with params, so the same sharding
rules apply leaf-for-leaf (FSDP shards optimizer state with parameters).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt, params, *, lr=3e-4, b1=0.9, b2=0.95,
                 eps=1e-8, wd=0.01):
    step = opt["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
