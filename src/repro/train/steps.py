"""Step builders: train (loss + AdamW), prefill, decode.

These are the functions the launcher jits with explicit in/out shardings;
they stay mesh-agnostic themselves (GSPMD propagates from the argument
shardings the launcher provides).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.config import ModelConfig
from .compression import compress_with_feedback, init_error
from .optim import adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    compress_grads: bool = False):
    """Returns step(params, opt, batch) -> (params, opt, metrics).

    batch: dict with tokens, labels (+ patches / enc_embeds stubs).
    """

    def step(params, opt, batch):
        def loss(p):
            return lm.loss_fn(p, cfg, batch["tokens"], batch["labels"],
                              enc_embeds=batch.get("enc_embeds"),
                              patches=batch.get("patches"))
        lval, grads = jax.value_and_grad(loss)(params)
        if compress_grads:
            err = opt.get("err")
            grads, err = compress_with_feedback(grads, err)
        new_params, new_inner = adamw_update(grads, opt["adam"], params, lr=lr)
        new_opt = {"adam": new_inner}
        if compress_grads:
            new_opt["err"] = err
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": lval, "grad_norm": gnorm}

    return step


def init_opt(cfg: ModelConfig, params, *, compress_grads: bool = False):
    opt = {"adam": adamw_init(params)}
    if compress_grads:
        opt["err"] = init_error(params)
    return opt


def make_prefill_step(cfg: ModelConfig):
    """step(params, batch) -> (last_logits, caches)."""

    def step(params, batch):
        B = batch["tokens"].shape[0]
        cache0 = lm.make_cache(cfg, B, 0, _cache_dtype(params))
        logits, caches = lm.forward(
            params, cfg, tokens=batch["tokens"], caches=cache0, pos=0,
            patches=batch.get("patches"), enc_embeds=batch.get("enc_embeds"))
        return logits[:, -1, :], caches

    return step


def make_decode_step(cfg: ModelConfig):
    """step(params, caches, batch) -> (logits, new_caches).

    batch["tokens"]: (B, 1); pos is the (static) context length carried
    by the cache shapes."""

    def step(params, caches, batch, *, pos: int):
        logits, new_caches = lm.forward(
            params, cfg, tokens=batch["tokens"], caches=caches, pos=pos,
            enc_embeds=batch.get("enc_embeds"))
        return logits[:, -1, :], new_caches

    return step


def _cache_dtype(params):
    leaf = jax.tree.leaves(params)[0]
    return leaf.dtype
