"""Training substrate: AdamW, step builders, gradient compression."""
from .optim import adamw_init, adamw_update  # noqa: F401
from .steps import make_train_step, make_prefill_step, make_decode_step  # noqa: F401
