"""Gradient compression: int8 quantization with error feedback.

At 1000+-node scale the gradient all-reduce over the DCN (pod) axis is
bandwidth-bound; int8 compression cuts it 4x vs fp32 (2x vs bf16).  Error
feedback accumulates the quantization residual locally and re-injects it
next step, which keeps SGD/Adam convergence (Seide et al., Karimireddy
et al.).

Two entry points:
  compress_with_feedback  pure per-leaf quantize/dequantize + residual —
                          wraps any gradient tree (used by train_step
                          when cfg enables compression)
  compressed_psum         shard_map-ready int8 all-reduce: quantize to a
                          shared scale, psum int32, dequantize.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(g32, scale):
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q


def compress_with_feedback(grads, error):
    """Returns (decompressed_grads, new_error).  error is a pytree like
    grads (initialize with zeros)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-12)
        q = _quant(g32, scale)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(error)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def init_error(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(g, axis_name: str):
    """int8-on-the-wire all-reduce for use inside shard_map: callers psum
    a shared max first (cheap scalar), then ship int8 payloads."""
    g32 = g.astype(jnp.float32)
    local_max = jnp.max(jnp.abs(g32))
    global_max = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(global_max / 127.0, 1e-12)
    q = _quant(g32, scale).astype(jnp.int32)       # int32 for the psum
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale).astype(g.dtype)
