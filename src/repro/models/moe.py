"""Grouped top-k mixture of experts (phi3.5-moe, deepseek-v2).

Dropless-ish capacity routing in the MaxText style: tokens are grouped by
sequence (group = one sequence), each expert gathers its top-C tokens per
group (C = S * k / E * capacity_factor), computes the FFN on the gathered
block, and scatter-adds weighted outputs back.  All index operations stay
group-local, so under the production mesh the groups shard over
(pod, data) and the expert axis shards over model (EP) with no
cross-shard gathers; the combine is a plain segment-sum.

FLOPs land at E * C ~ k * capacity_factor per token — near the ideal
active-parameter count, so the roofline's MODEL_FLOPS / HLO_FLOPs ratio
stays honest (a dense all-experts fallback would show E/k x waste).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], (d, E), dtype) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (E, d, ff), dtype) * d ** -0.5,
        "w_up": jax.random.normal(ks[2], (E, d, ff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(ks[3], (E, ff, d), dtype) * ff ** -0.5,
    }
    if cfg.n_shared_experts:
        sf = ff * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(kss[0], (d, sf), dtype) * d ** -0.5,
            "w_up": jax.random.normal(kss[1], (d, sf), dtype) * d ** -0.5,
            "w_down": jax.random.normal(kss[2], (sf, d), dtype) * sf ** -0.5,
        }
    return p


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, d) — B is the group axis (sharded over pod/data)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    C = max(1, int(S * k / E * cfg.capacity_factor))
    C = min(C, S)

    logits = (x @ p["router"]).astype(jnp.float32)        # (B, S, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                  # (B, S, k)
    topv = topv / (topv.sum(axis=-1, keepdims=True) + 1e-9)
    # dense (B, S, E) combine weights, zero outside top-k
    W = jnp.zeros((B, S, E), jnp.float32)
    W = jax.vmap(jax.vmap(lambda w, v, i: w.at[i].set(v)))(W, topv, topi)

    # per (group, expert): select top-C tokens by weight
    We = jnp.swapaxes(W, 1, 2)                            # (B, E, S)
    sel_w, sel_i = jax.lax.top_k(We, C)                   # (B, E, C)
    xg = jnp.take_along_axis(x[:, None, :, :],            # (B, E, C, d)
                             sel_i[..., None], axis=2)
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("becd,edf->becf", xg, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", xg, p["w_up"])
    y_e = jnp.einsum("becf,efd->becd", h, p["w_down"])    # (B, E, C, d)
    y_e = y_e * sel_w[..., None].astype(y_e.dtype)
    # scatter-add back to token positions (group-local segment sum)
    out = jnp.zeros((B, S, d), y_e.dtype)
    flat_i = sel_i.reshape(B, E * C)
    flat_y = y_e.reshape(B, E * C, d)
    out = jax.vmap(lambda o, i, ys: o.at[i].add(ys))(out, flat_i, flat_y)

    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + (act(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return out.astype(x.dtype)
