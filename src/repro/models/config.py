"""Architecture configuration for the LM substrate.

One dataclass covers all ten assigned architectures; the `pattern` field
cycles layer kinds over depth (e.g. gemma3's 5 local : 1 global, or
recurrentgemma's rglru-rglru-local).  Layers with identical parameter
shapes inside a repeating unit are stacked and scanned (lax.scan) so the
lowered HLO stays one-unit-sized regardless of depth.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    rope_theta: float = 10000.0
    window: int = 0                       # local-attention window
    # block pattern, cycled over n_layers: attn | local | ssm | rglru
    pattern: tuple = ("attn",)
    # mlp
    d_ff: int = 0
    mlp_act: str = "silu"                 # silu | gelu
    mlp_gated: bool = True                # False: classic 2-matrix MLP
    # moe
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # mla (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    conv_width: int = 4
    # rglru (recurrentgemma)
    lru_width: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    is_enc_dec: bool = False
    # modality frontend stub: None | vision | audio
    frontend: str | None = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def unit(self) -> tuple:
        return self.pattern

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> tuple:
        """Layers left over after whole units (unrolled separately)."""
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return all(k == "ssm" for k in self.pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if no layer kind holds a full-sequence KV cache, or only a
        bounded fraction does (local windows / recurrent state)."""
        return all(k in ("ssm", "rglru", "local") for k in self.pattern) or \
            self.pattern.count("attn") * 6 <= len(self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and reporting.  Counts follow init_params exactly."""
        d, V = self.d_model, self.vocab
        total = V * d                                  # embedding
        if not self.tie_embeddings:
            total += V * d
        kinds = [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]
        for kind in kinds:
            total += self._block_params(kind)
        total += d                                     # final norm
        if self.is_enc_dec:
            total += self.enc_layers * (self._attn_params() + self._mlp_params(self.d_ff) + 3 * d)
        return total

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: only routed-in experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full_moe = 3 * d * self.moe_d_ff * self.n_experts
        act_moe = 3 * d * self.moe_d_ff * (self.n_experts_per_tok + self.n_shared_experts)
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.pattern[i % len(self.pattern)] in ("attn", "local"))
        return self.param_count() - n_moe_layers * (full_moe - act_moe) \
            - n_moe_layers * d * self.n_experts  # router counted once

    # ---- per-kind parameter counts (mirrors lm.init exactly) ----
    def _attn_params(self) -> int:
        d, H, Hkv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.hd
        if self.use_mla:
            ql, kl, rd = self.q_lora_rank, self.kv_lora_rank, self.rope_head_dim
            n = d * ql + ql * H * (hd + rd)            # q lora
            n += d * (kl + rd)                          # kv down + shared rope
            n += kl * H * hd * 2                        # k_up, v_up
            n += H * hd * d                             # out
            n += ql + kl                                # lora norms
            return n
        n = d * H * hd + 2 * d * Hkv * hd + H * hd * d
        if self.qkv_bias:
            n += H * hd + 2 * Hkv * hd
        if self.qk_norm:
            n += 2 * hd
        return n

    def _mlp_params(self, ff: int) -> int:
        return 3 * self.d_model * ff

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "ssm":
            di = self.ssm_heads * self.ssm_head_dim
            n = d * (2 * di + 2 * self.ssm_state + self.ssm_heads)  # in_proj
            n += self.conv_width * (di + 2 * self.ssm_state)        # conv
            n += self.ssm_heads * 2 + di                            # A, D, dt_bias? (A,D per head + skip)
            n += di * d                                              # out
            return n + d                                             # norm
        if kind == "rglru":
            w = self.lru_width or d
            n = d * w * 2 + self.conv_width * w                      # in (x,gate) + conv
            n += 2 * w * (w // 8) * 8 if False else 2 * w * w // 4   # block-diag gates (w x w/4)
            n += w                                                   # Lambda
            n += w * d                                               # out
            return n + d
        # attention-ish kinds
        n = self._attn_params() + 2 * d                              # + 2 norms
        if self.is_moe:
            n += self.n_experts * 3 * d * self.moe_d_ff
            n += self.n_shared_experts * 3 * d * self.moe_d_ff
            n += d * self.n_experts
        else:
            n += self._mlp_params(self.d_ff)
        return n
