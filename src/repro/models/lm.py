"""Model assembly: parameter init, scanned forward pass, caches, loss.

Depth is organized as repeating *units* (cfg.pattern).  Parameters of the
u-th unit's s-th slot live in params["units"][s] stacked along a leading
n_units axis; the forward pass lax.scans one unit body over that stack,
so the lowered HLO contains a single unit regardless of depth (62-layer
gemma3 compiles as one 6-layer unit + a 2-layer tail).  Caches mirror the
same layout.

Three entry points, shared by every architecture:
  forward(..., tokens|embeds, caches=None, pos=0)       train / prefill
  forward(..., caches=filled, pos=ctx_len)              decode (S=1)
  enc-dec (whisper): encode() runs the non-causal encoder stack on the
  frontend-stub embeddings; decoder blocks add cross-attention over it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_attn, apply_mla, apply_mlp, init_attn, init_mla,
                     init_mlp, init_norm, rmsnorm)
from .moe import apply_moe, init_moe
from .seqmix import apply_rglru, apply_ssm, init_rglru, init_ssm


# ---------------------------------------------------------------------------
# Block init / apply (one layer).
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm": init_norm(cfg, dtype), "ssm": init_ssm(ks[0], cfg, dtype)}
    if kind == "rglru":
        return {"norm1": init_norm(cfg, dtype), "rglru": init_rglru(ks[0], cfg, dtype),
                "norm2": init_norm(cfg, dtype), "mlp": init_mlp(ks[1], cfg, dtype)}
    # attention kinds: attn | local | xdec (decoder w/ cross-attention)
    p = {"norm1": init_norm(cfg, dtype),
         "attn": (init_mla(ks[0], cfg, dtype) if cfg.use_mla
                  else init_attn(ks[0], cfg, dtype)),
         "norm2": init_norm(cfg, dtype)}
    if kind == "xdec":
        p["xattn"] = init_attn(ks[2], cfg, dtype)
        p["norm_x"] = init_norm(cfg, dtype)
    if cfg.is_moe:
        p["mlp"] = init_moe(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[1], cfg, dtype)
    return p


def apply_block(p, x, kind: str, cfg: ModelConfig, *, cache=None, pos=0,
                causal=True, enc_out=None):
    if kind == "ssm":
        y, nc = apply_ssm(p["ssm"], rmsnorm(x, p["norm"], cfg.norm_eps), cfg, cache=cache)
        return x + y, nc
    if kind == "rglru":
        y, nc = apply_rglru(p["rglru"], rmsnorm(x, p["norm1"], cfg.norm_eps), cfg, cache=cache)
        h = x + y
        h = h + apply_mlp(p["mlp"], rmsnorm(h, p["norm2"], cfg.norm_eps), cfg)
        return h, nc
    window = cfg.window if kind == "local" else 0
    attn_fn = apply_mla if cfg.use_mla else apply_attn
    y, nc = attn_fn(p["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps), cfg,
                    window=window, cache=cache, pos=pos, causal=causal)
    h = x + y
    if kind == "xdec":
        # cross-attention: kv from the encoder output (no cache growth).
        q_in = rmsnorm(h, p["norm_x"], cfg.norm_eps)
        y, _ = apply_attn(p["xattn"], q_in, cfg, cache=None, pos=0, causal=False,
                          kv_override=enc_out)
        h = h + y
    if "mlp" in p:
        mlp_fn = apply_moe if cfg.is_moe else apply_mlp
        h = h + mlp_fn(p["mlp"], rmsnorm(h, p["norm2"], cfg.norm_eps), cfg)
    return h, nc


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------

def _slot_cache_shape(kind: str, cfg: ModelConfig, B: int, ctx: int, dtype):
    """Empty/filled cache pytree for ONE layer of `kind` with ctx tokens."""
    if kind == "ssm":
        return {"state": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
                "conv": jnp.zeros((B, cfg.conv_width - 1,
                                   cfg.ssm_heads * cfg.ssm_head_dim + 2 * cfg.ssm_state), dtype)}
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return {"h": jnp.zeros((B, w), dtype),
                "conv": jnp.zeros((B, cfg.conv_width - 1, w), dtype)}
    keep = min(ctx, cfg.window) if kind == "local" and cfg.window else ctx
    if cfg.use_mla:
        return {"latent": jnp.zeros((B, keep, cfg.kv_lora_rank + cfg.rope_head_dim), dtype)}
    return {"k": jnp.zeros((B, keep, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((B, keep, cfg.n_kv_heads, cfg.hd), dtype)}


def make_cache(cfg: ModelConfig, B: int, ctx: int, dtype=jnp.bfloat16):
    """Stacked per-slot caches matching the scanned parameter layout."""
    units = [jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape),
                          _slot_cache_shape(kind, cfg, B, ctx, dtype))
             for kind in cfg.unit]
    tail = [_slot_cache_shape(kind, cfg, B, ctx, dtype) for kind in cfg.tail]
    return {"units": units, "tail": tail}


# ---------------------------------------------------------------------------
# Parameter init.
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab
    params = {"embed": jax.random.normal(keys[0], (V, d), dtype) * 0.02,
              "final_norm": init_norm(cfg, dtype)}
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(keys[1], (d, V), dtype) * d ** -0.5

    def stacked(base_key, kind, count):
        ks = jax.random.split(base_key, count)
        return jax.vmap(lambda k: init_block(k, kind, cfg, dtype))(ks)

    params["units"] = [stacked(jax.random.fold_in(keys[2], s), kind, cfg.n_units)
                       for s, kind in enumerate(cfg.unit)]
    params["tail"] = [init_block(jax.random.fold_in(keys[3], s), kind, cfg, dtype)
                      for s, kind in enumerate(cfg.tail)]
    if cfg.is_enc_dec:
        params["enc_units"] = [stacked(keys[4], "attn", cfg.enc_layers)]
        params["enc_norm"] = init_norm(cfg, dtype)
    return params


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    import math
    shapes = jax.eval_shape(lambda k: init_params(k, cfg, jnp.bfloat16),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------

def _scan_units(params_units, caches_units, x, cfg, *, pos, causal, enc_out,
                unit=None):
    """lax.scan one unit body over the stacked unit parameters."""
    new_caches = []
    kinds = unit if unit is not None else cfg.unit
    for s, kind in enumerate(kinds):
        pstack = params_units[s]
        cstack = caches_units[s] if caches_units is not None else None
        if cstack is None:
            def body_nc(carry, p_t, kind=kind):
                h, _ = apply_block(p_t, carry, kind, cfg, cache=None, pos=pos,
                                   causal=causal, enc_out=enc_out)
                return h, 0.0
            x, _ = jax.lax.scan(body_nc, x, pstack)
            new_caches.append(None)
        else:
            def body(carry, xs, kind=kind):
                p_t, c_t = xs
                h, nc = apply_block(p_t, carry, kind, cfg, cache=c_t, pos=pos,
                                    causal=causal, enc_out=enc_out)
                return h, nc
            x, ncs = jax.lax.scan(body, x, (pstack, cstack))
            new_caches.append(ncs)
    return x, new_caches


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, caches=None,
            pos=0, enc_embeds=None, patches=None):
    """Returns (logits, new_caches).

    tokens: (B, S) int32 — standard path.
    embeds: (B, S, d) — full frontend-stub path (embeds replace tokens).
    patches: (B, P, d) — vision-stub path: patch embeddings overwrite the
             first P positions of the token embedding (phi-3-vision).
    enc_embeds: (B, S_enc, d) — encoder input for enc-dec models.
    """
    d = cfg.d_model
    if embeds is not None:
        x = embeds
    else:
        x = params["embed"][tokens] * jnp.asarray(d ** 0.5, params["embed"].dtype)
        if patches is not None:
            x = jax.lax.dynamic_update_slice(x, patches.astype(x.dtype), (0, 0, 0))

    enc_out = None
    if cfg.is_enc_dec:
        assert enc_embeds is not None, "enc-dec needs encoder inputs"
        e, _ = _scan_units(params["enc_units"], None, enc_embeds,
                           cfg, pos=0, causal=False, enc_out=None, unit=("attn",))
        enc_out = rmsnorm(e, params["enc_norm"], cfg.norm_eps)

    caches_units = caches["units"] if caches is not None else None
    x, new_unit_caches = _scan_units(params["units"], caches_units, x, cfg,
                                     pos=pos, causal=True, enc_out=enc_out)
    new_tail = []
    for s, kind in enumerate(cfg.tail):
        c = caches["tail"][s] if caches is not None else None
        x, nc = apply_block(params["tail"][s], x, kind, cfg, cache=c, pos=pos,
                            causal=True, enc_out=enc_out)
        new_tail.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    new_caches = {"units": new_unit_caches, "tail": new_tail} if caches is not None else None
    return logits, new_caches


def loss_fn(params, cfg: ModelConfig, tokens, labels, embeds=None,
            enc_embeds=None, patches=None):
    logits, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                        enc_embeds=enc_embeds, patches=patches)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
