"""Sequence mixers without attention: Mamba2 SSD and RG-LRU.

Mamba2 (SSD, state-space duality form): scalar-per-head decay a_t =
exp(dt * A_h); chunked evaluation — quadratic attention-like path inside
chunks of Q tokens, linear state recurrence across chunks (lax.scan).
Decode is the O(1) recurrence  S <- a S + dt * B x;  y = C S + D x.

RG-LRU (recurrentgemma): gated linear recurrence
  r_t = sigmoid(W_r x), i_t = sigmoid(W_i x)
  log a_t = -c * softplus(L) * r_t
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
evaluated with an associative scan (log-depth) for train/prefill and the
same O(1) update for decode, preceded by a width-4 causal conv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# Mamba2 SSD.
# ---------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_heads * cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 4)
    return {
        # projections for z (gate), x, B, C, dt
        "w_in": jax.random.normal(ks[0], (d, 2 * di + 2 * N + cfg.ssm_heads), dtype) * d ** -0.5,
        "conv": jax.random.normal(ks[1], (cfg.conv_width, di + 2 * N), dtype) * 0.1,
        "A_log": jnp.zeros((cfg.ssm_heads,), dtype),
        "D": jnp.ones((cfg.ssm_heads,), dtype),
        "dt_bias": jnp.zeros((cfg.ssm_heads,), dtype),
        "w_out": jax.random.normal(ks[2], (di, d), dtype) * di ** -0.5,
        "gate_norm": jnp.zeros((di,), dtype),
    }


def _causal_conv(x, w):
    """x: (B, S, C); w: (W, C) depthwise causal conv via shifted adds."""
    W = w.shape[0]
    out = x * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[W - 1 - i]
    return out


def _ssd_chunked(xh, a, B_, C_, chunk):
    """SSD scan.  xh: (B,S,H,P) dt-scaled inputs; a: (B,S,H) decay in (0,1];
    B_, C_: (B,S,N).  Returns (B,S,H,P)."""
    B, S, H, P = xh.shape
    N = B_.shape[-1]
    nc = S // chunk
    xc = xh.reshape(B, nc, chunk, H, P)
    ac = a.reshape(B, nc, chunk, H)
    Bc = B_.reshape(B, nc, chunk, N)
    Cc = C_.reshape(B, nc, chunk, N)
    loga = jnp.log(ac + 1e-20)
    cum = jnp.cumsum(loga, axis=2)                       # (B,nc,Q,H)
    # intra-chunk: y_t += C_t . sum_{s<=t} prod_{s<u<=t} a_u B_s x_s
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)           # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", cb, decay, xc)
    # chunk states: S_c = sum_s prod_{s<u<=Q} a_u B_s x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)              # (B,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, tail, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def step(carry, inp):
        s_prev = carry                                    # (B,H,P,N)
        st, dec = inp                                     # (B,H,P,N), (B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    init = jnp.zeros((B, H, P, N), xh.dtype)
    s_final, s_in = jax.lax.scan(step, init,
                                 (jnp.swapaxes(states, 0, 1), jnp.swapaxes(chunk_decay, 0, 1)))
    s_in = jnp.swapaxes(s_in, 0, 1)                      # (B,nc,H,P,N) state entering chunk
    inter_decay = jnp.exp(cum)                           # (B,nc,Q,H)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", Cc, inter_decay, s_in)
    return (y_intra + y_inter).reshape(B, S, H, P), s_final


def apply_ssm(p, x, cfg: ModelConfig, *, cache=None, **_):
    """Returns (out, new_cache); cache = dict(state=(B,H,P,N), conv=(B,W-1,C))."""
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = H * P
    proj = x @ p["w_in"]
    z, xin, B_, C_, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, B_, C_], axis=-1)
    if cache is not None and S == 1:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)   # (B,W,C)
        conv_out = (hist * p["conv"][None]).sum(axis=1, keepdims=True)
        new_conv = hist[:, 1:, :]
    else:
        conv_out = _causal_conv(conv_in, p["conv"])
        new_conv = conv_in[:, -(cfg.conv_width - 1):, :]
    conv_out = jax.nn.silu(conv_out)
    xin, B_, C_ = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                   # (H,)
    a = jnp.exp(dt * A)                                            # (B,S,H)
    xh = xin.reshape(B, S, H, P) * dt[..., None].astype(x.dtype)
    if cache is not None and S == 1:
        s_prev = cache["state"]                                    # (B,H,P,N)
        s_new = s_prev * a[:, 0, :, None, None] \
            + jnp.einsum("bhp,bn->bhpn", xh[:, 0], B_[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", C_[:, 0], s_new)[:, None]   # (B,1,H,P)
        new_state = s_new
    else:
        pad = (-S) % cfg.ssm_chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
            B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
            C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        y, s_final = _ssd_chunked(xh.astype(jnp.float32), a, B_.astype(jnp.float32),
                                  C_.astype(jnp.float32), cfg.ssm_chunk)
        y = y[:, :S]
        xh = xh[:, :S]                                # drop chunk padding
        new_state = s_final.astype(x.dtype)           # decode handoff
    y = y.astype(x.dtype) + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
                          + 1e-6).astype(x.dtype) * (1.0 + p["gate_norm"])
    out = y @ p["w_out"]
    return out, {"state": new_state, "conv": new_conv}


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma).
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 5)
    return {
        "w_x": jax.random.normal(ks[0], (d, w), dtype) * d ** -0.5,
        "w_y": jax.random.normal(ks[1], (d, w), dtype) * d ** -0.5,
        "conv": jax.random.normal(ks[2], (cfg.conv_width, w), dtype) * 0.1,
        "w_r": jax.random.normal(ks[3], (w, w), dtype) * w ** -0.5,
        "w_i": jax.random.normal(ks[4], (w, w), dtype) * w ** -0.5,
        "Lambda": jnp.full((w,), 2.0, dtype),            # softplus -> decay
        "w_out": jax.random.normal(jax.random.fold_in(key, 9), (w, d), dtype) * w ** -0.5,
    }


def apply_rglru(p, x, cfg: ModelConfig, *, cache=None, **_):
    """Returns (out, new_cache); cache = dict(h=(B,w), conv=(B,W-1,w))."""
    B, S, d = x.shape
    gate_branch = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    if cache is not None and S == 1:
        hist = jnp.concatenate([cache["conv"], u], axis=1)
        u_c = (hist * p["conv"][None]).sum(axis=1, keepdims=True)
        new_conv = hist[:, 1:, :]
    else:
        u_c = _causal_conv(u, p["conv"])
        new_conv = u[:, -(cfg.conv_width - 1):, :]
    r = jax.nn.sigmoid(u_c @ p["w_r"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u_c @ p["w_i"])
    log_a = -RGLRU_C * jax.nn.softplus(p["Lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                   # (B,S,w)
    gated = (i * u_c).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    v = beta * gated
    if cache is not None and S == 1:
        h = a[:, 0] * cache["h"] + v[:, 0]
        y = h[:, None, :]
        new_h = h
    else:
        def combine(c1, c2):
            a1, v1 = c1
            a2, v2 = c2
            return a1 * a2, v1 * a2 + v2
        a_s, y = jax.lax.associative_scan(combine, (a, v), axis=1)
        new_h = y[:, -1, :]
    out = (y.astype(x.dtype) * gate_branch) @ p["w_out"]
    return out, {"h": new_h.astype(x.dtype), "conv": new_conv}
