"""Shared neural layers: RMSNorm, RoPE, (GQA/local/softcap) attention,
MLA attention with compressed-latent cache, gated MLP.

Parameters are plain nested dicts of jnp arrays; every apply function is
pure.  Attention supports three modes:
  train/prefill  full sequence, optionally returning a KV cache
  decode         one new token against a cache (static shapes)
Local attention masks by window; GQA repeats KV heads at compute time.
The Pallas flash kernel (kernels/flash_attn) is the TPU production path;
the jnp path below is the portable reference the dry-run lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def rmsnorm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * (1.0 + w)


def init_norm(cfg: ModelConfig, dtype):
    return jnp.zeros((cfg.d_model,), dtype=dtype)


def rope(x, positions, theta: float):
    """x: (..., S, H, D) rotary over last dim; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Standard (GQA) attention.
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, H * hd), dtype) * scale,
        "wk": jax.random.normal(ks[1], (d, Hkv * hd), dtype) * scale,
        "wv": jax.random.normal(ks[2], (d, Hkv * hd), dtype) * scale,
        "wo": jax.random.normal(ks[3], (H * hd, d), dtype) * (H * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _mask(sq, sk, q_start, k_start, window, dtype):
    """(sq, sk) additive mask: causal plus optional local window.

    k_start is the global position of the first key — nonzero when a
    local layer's cache keeps only the last `window` positions."""
    q_pos = q_start + jnp.arange(sq)[:, None]
    k_pos = k_start + jnp.arange(sk)[None, :]
    ok = q_pos >= k_pos
    if window:
        ok &= (q_pos - k_pos) < window
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


# Above this many query positions, attention runs chunked (perf
# iteration #2): the (B,H,S,S) score tensor never materializes — peak
# activation drops by S/CHUNK_Q and the chunk body is rematerialized in
# the backward pass (flash-attention memory behaviour; the Pallas kernel
# in kernels/flash_attn is the real-TPU twin of this lowering).
CHUNK_Q = 2048


def _attn_dense(q, k, v, cfg: ModelConfig, *, q_start, k_start, window, causal):
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (D ** -0.5)
    if cfg.attn_softcap:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    if causal:
        s = s + _mask(S, k.shape[1], q_start, k_start, window, s.dtype)[None, None]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.reshape(B, S, H * D)


def attn_scores(q, k, v, cfg: ModelConfig, *, q_start=0, k_start=0, window=0,
                causal=True):
    """q: (B,S,H,D); k/v: (B,Sk,Hkv,D) -> (B,S,H*D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    if causal and S > CHUNK_Q and S % CHUNK_Q == 0:
        nq = S // CHUNK_Q
        qs = jnp.swapaxes(q.reshape(B, nq, CHUNK_Q, H, D), 0, 1)

        def chunk(args):
            i, qc = args
            return _attn_dense(qc, k, v, cfg, q_start=q_start + i * CHUNK_Q,
                               k_start=k_start, window=window, causal=True)

        outs = jax.lax.map(jax.checkpoint(chunk), (jnp.arange(nq), qs))
        return jnp.swapaxes(outs, 0, 1).reshape(B, S, H * D)
    return _attn_dense(q, k, v, cfg, q_start=q_start, k_start=k_start,
                       window=window, causal=causal)


def apply_attn(p, x, cfg: ModelConfig, *, window=0, cache=None, pos=0,
               causal=True, kv_override=None):
    """Returns (out, new_cache).  cache = dict(k=(B,Sc,Hkv,D), v=...) holding
    the last Sc positions (Sc = window for local layers); decode appends
    the current token's kv.  kv_override: cross-attention — kv computed
    from the given memory, no rope, no cache."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kv_src = kv_override if kv_override is not None else x
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, kv_src.shape[1], Hkv, hd)
    v = v.reshape(B, kv_src.shape[1], Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if kv_override is not None:
        out = attn_scores(q, k, v, cfg, causal=False)
        return out @ p["wo"], None
    positions = pos + jnp.arange(S)
    q = rope(q, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    if cache is not None:
        k_all = jnp.concatenate([cache["k"], k], axis=1)
        v_all = jnp.concatenate([cache["v"], v], axis=1)
    else:
        k_all, v_all = k, v
    k_start = pos + S - k_all.shape[1]
    out = attn_scores(q, k_all, v_all, cfg, q_start=pos, k_start=k_start,
                      window=window, causal=causal)
    if cache is not None and window and k_all.shape[1] > window:
        k_all = k_all[:, -window:]
        v_all = v_all[:, -window:]
    new_cache = {"k": k_all, "v": v_all}
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v2).  The KV cache stores
# only the compressed latent (kv_lora_rank + rope_head_dim per token).
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ql, kl, rd = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "q_down": jax.random.normal(ks[0], (d, ql), dtype) * s,
        "q_norm": jnp.zeros((ql,), dtype),
        "q_up": jax.random.normal(ks[1], (ql, H * (hd + rd)), dtype) * ql ** -0.5,
        "kv_down": jax.random.normal(ks[2], (d, kl + rd), dtype) * s,
        "kv_norm": jnp.zeros((kl,), dtype),
        "k_up": jax.random.normal(ks[3], (kl, H * hd), dtype) * kl ** -0.5,
        "v_up": jax.random.normal(ks[4], (kl, H * hd), dtype) * kl ** -0.5,
        "wo": jax.random.normal(ks[5], (H * hd, d), dtype) * (H * hd) ** -0.5,
    }


def apply_mla(p, x, cfg: ModelConfig, *, cache=None, pos=0, causal=True, **_):
    B, S, d = x.shape
    H, hd, rd, kl = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora_rank
    ql = cfg.q_lora_rank
    q = rmsnorm(x @ p["q_down"], p["q_norm"], cfg.norm_eps) @ p["q_up"]
    q = q.reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    kv = x @ p["kv_down"]                             # (B,S,kl+rd)
    latent = rmsnorm(kv[..., :kl], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., kl:][:, :, None, :]              # (B,S,1,rd) shared head
    positions = pos + jnp.arange(S)
    posb = jnp.broadcast_to(positions, (B, S))
    q_rope = rope(q_rope, posb, cfg.rope_theta)
    k_rope = rope(k_rope, posb, cfg.rope_theta)
    lat_rope = jnp.concatenate([latent, k_rope[:, :, 0, :]], axis=-1)  # cacheable
    if cache is not None:
        lat_all = jnp.concatenate([cache["latent"], lat_rope], axis=1)
    else:
        lat_all = lat_rope
    latent_all, k_rope_all = lat_all[..., :kl], lat_all[..., kl:]
    k_nope = (latent_all @ p["k_up"]).reshape(B, -1, H, hd)
    vv = (latent_all @ p["v_up"]).reshape(B, -1, H, hd)
    s = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
         + jnp.einsum("bqhr,bkr->bhqk", q_rope, k_rope_all)).astype(jnp.float32)
    s = s * ((hd + rd) ** -0.5)
    if causal:
        k_start = pos + S - lat_all.shape[1]
        s = s + _mask(S, lat_all.shape[1], pos, k_start, 0, s.dtype)[None, None]
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vv).reshape(B, S, H * hd)
    return out @ p["wo"], {"latent": lat_all}


# ---------------------------------------------------------------------------
# Gated MLP.
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, ff: int | None = None):
    d = cfg.d_model
    ff = ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_gate": jax.random.normal(ks[0], (d, ff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], (ff, d), dtype) * ff ** -0.5,
    }
    if cfg.mlp_gated:
        p["w_up"] = jax.random.normal(ks[1], (d, ff), dtype) * d ** -0.5
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(x @ p["w_gate"])
    if cfg.mlp_gated:
        h = h * (x @ p["w_up"])
    return h @ p["w_down"]
