"""Runtime substrate: checkpoint atomicity/restore, pipeline determinism,
straggler detection, elastic planning, gradient compression."""
import os

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data.pipeline import TokenPipeline
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import StragglerDetector, elastic_mesh_plan
from repro.train.compression import (compress_with_feedback, init_error)


def _tree():
    k = jax.random.PRNGKey(0)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    params = _tree()
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    for step in (10, 20, 30):
        scaled = jax.tree.map(lambda x: x * step, params)
        mgr.save(step, scaled, opt, extra={"pipeline": {"step": step,
                                                        "seed": 1234, "shard": 0}})
    assert mgr.all_steps() == [20, 30]          # keep-last-2 GC
    got, gopt, extra = mgr.restore(30, params, opt)
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.asarray(params["a"]) * 30)
    assert extra["pipeline"]["step"] == 30


def test_checkpoint_atomicity(tmp_path):
    """A stale tmp dir (crash mid-write) must not be visible as a step."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert mgr.all_steps() == []
    mgr.save(5, _tree())
    assert mgr.all_steps() == [5]


def test_checkpoint_async_double_buffer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, _tree())
    mgr.save(2, _tree())     # waits for the in-flight write first
    mgr.wait()
    assert mgr.all_steps() == [1, 2]


def test_pipeline_determinism_across_restore():
    p1 = TokenPipeline(vocab=100, seq_len=16, batch=2)
    batches = [p1.next_batch() for _ in range(5)]
    st_ = p1.state_dict()
    p2 = TokenPipeline(vocab=100, seq_len=16, batch=2)
    p2.load_state_dict({"step": 3, "seed": 1234, "shard": 0})
    b3 = p2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    # shards draw disjoint streams
    p3 = TokenPipeline(vocab=100, seq_len=16, batch=2, shard=1, num_shards=2)
    assert not np.array_equal(p3.next_batch()["tokens"], batches[0]["tokens"])


def test_straggler_detection():
    det = StragglerDetector(threshold=2.0, patience=2, timeout_s=10.0)
    now = 1000.0
    excluded = []
    for t in range(6):                      # periodic heartbeat rounds
        for w in range(4):
            dt = 1.0 if w != 3 else 5.0     # worker 3 is slow
            det.report(w, dt, now=now + t)
        excluded = det.evaluate(now=now + t)
    assert excluded == [3]
    # dead worker: stops reporting past the timeout
    det2 = StragglerDetector(timeout_s=5.0)
    det2.report(0, 1.0, now=0.0)
    det2.report(1, 1.0, now=0.0)
    det2.report(0, 1.0, now=20.0)
    assert det2.evaluate(now=20.0) == [1]


def test_elastic_mesh_plan():
    plan = elastic_mesh_plan(512, excluded=16, model_parallel=16)
    assert plan["mesh_shape"] == (16, 16)
    assert plan["devices_used"] == 256
    plan = elastic_mesh_plan(512, excluded=0, model_parallel=16)
    assert plan["mesh_shape"] == (32, 16)
    with pytest.raises(RuntimeError):
        elastic_mesh_plan(20, excluded=10, model_parallel=16)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_property(seed):
    """Quantize-with-feedback: per-step error is bounded by the int8 bin
    width, and the residual carries to the next step (EF contract)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32,)) * rng.uniform(0.1, 10))}
    err = init_error(g)
    deq, err2 = compress_with_feedback(g, err)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale * 0.5 + 1e-9
    np.testing.assert_allclose(np.asarray(err2["w"]),
                               np.asarray(g["w"] - deq["w"]), rtol=1e-4,
                               atol=1e-5)   # f32 arithmetic noise


def test_compression_accumulated_bias_vanishes():
    """Over repeated steps on a constant gradient, EF makes the *average*
    applied update converge to the true gradient."""
    g = {"w": jnp.asarray(np.linspace(-1.0, 1.0, 16) * 0.01)}
    err = init_error(g)
    total = jnp.zeros(16)
    steps = 50
    for _ in range(steps):
        deq, err = compress_with_feedback(g, err)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / steps), np.asarray(g["w"]),
                               atol=2e-4)
