"""RNS-BFV scheme correctness: roundtrips, homomorphic ops, noise model
soundness (analytic bound must never exceed exact measured budget)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.bfv import BFVContext
from repro.core.encoder import BatchEncoder
from repro.core.params import test_params as _tiny_params


@pytest.fixture(scope="module")
def ctx():
    p = _tiny_params()
    c = BFVContext(p, seed=5)
    return c, c.keygen(), BatchEncoder(p)


def test_encrypt_decrypt_roundtrip(ctx):
    c, keys, enc = ctx
    rng = np.random.default_rng(0)
    v = rng.integers(0, c.params.t, c.params.n)
    ct = c.encrypt(enc.encode(v), keys.pk)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(ct, keys.sk))), v)


def test_homomorphic_add_sub_neg(ctx):
    c, keys, enc = ctx
    t, n = c.params.t, c.params.n
    rng = np.random.default_rng(1)
    a, b = rng.integers(0, t, n), rng.integers(0, t, n)
    ca, cb = c.encrypt(enc.encode(a), keys.pk), c.encrypt(enc.encode(b), keys.pk)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.add(ca, cb), keys.sk))), (a + b) % t)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.sub(ca, cb), keys.sk))), (a - b) % t)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.neg(ca), keys.sk))), (-a) % t)


def test_homomorphic_mul_and_plain_ops(ctx):
    c, keys, enc = ctx
    t, n = c.params.t, c.params.n
    rng = np.random.default_rng(2)
    a, b = rng.integers(0, t, n), rng.integers(0, t, n)
    ca, cb = c.encrypt(enc.encode(a), keys.pk), c.encrypt(enc.encode(b), keys.pk)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.mul(ca, cb, keys.rlk), keys.sk))),
                          a * b % t)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.mul_plain(ca, enc.encode(b)), keys.sk))),
                          a * b % t)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.mul_scalar(ca, 7), keys.sk))),
                          a * 7 % t)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.add_scalar(ca, 9), keys.sk))),
                          (a + 9) % t)
    assert np.array_equal(np.asarray(enc.decode(c.decrypt(c.sub_from_scalar(1, ca), keys.sk))),
                          (1 - a) % t)


def test_rotation_and_rowswap(ctx):
    c, keys, enc = ctx
    t, n = c.params.t, c.params.n
    half = n // 2
    v = np.arange(n) % t
    ct = c.encrypt(enc.encode(v), keys.pk)
    for step in (1, 3, half - 1):
        got = np.asarray(enc.decode(c.decrypt(c.rotate_rows(ct, step, keys.gks), keys.sk)))
        exp = np.concatenate([np.roll(v[:half], -step), np.roll(v[half:], -step)]) % t
        assert np.array_equal(got, exp), step
    got = np.asarray(enc.decode(c.decrypt(c.swap_rows(ct, keys.gks), keys.sk)))
    assert np.array_equal(got, np.concatenate([v[half:], v[:half]]) % t)


def test_sum_slots(ctx):
    c, keys, enc = ctx
    t, n = c.params.t, c.params.n
    rng = np.random.default_rng(3)
    v = rng.integers(0, t, n)
    ct = c.encrypt(enc.encode(v), keys.pk)
    got = np.asarray(enc.decode(c.decrypt(c.sum_slots(ct, keys.gks), keys.sk)))
    assert np.all(got == int(v.sum()) % t)


def test_analytic_noise_is_conservative(ctx):
    """Analytic budget must lower-bound the exact secret-key measurement
    at every depth until failure."""
    c, keys, enc = ctx
    rng = np.random.default_rng(4)
    v = rng.integers(0, c.params.t, c.params.n)
    ct = c.encrypt(enc.encode(v), keys.pk)
    exact = c.noise_budget_exact(ct, keys.sk)
    assert ct.budget <= exact + 1e-6
    cur = ct
    for _ in range(3):
        cur = c.mul(cur, cur, keys.rlk)
        exact = c.noise_budget_exact(cur, keys.sk)
        if exact <= 0:
            break
        assert cur.budget <= exact + 1e-6, "analytic bound too optimistic"


@given(st.integers(0, 7680), st.integers(0, 7680))
@settings(max_examples=10, deadline=None)
def test_homomorphism_property(ctx, x, y):
    """Dec(E(x) op E(y)) == x op y (mod t) — the core HE invariant."""
    c, keys, enc = ctx
    t = c.params.t
    cx = c.encrypt(enc.encode(np.full(c.params.n, x)), keys.pk)
    cy = c.encrypt(enc.encode(np.full(c.params.n, y)), keys.pk)
    add = int(np.asarray(enc.decode(c.decrypt(c.add(cx, cy), keys.sk)))[0])
    mul = int(np.asarray(enc.decode(c.decrypt(c.mul(cx, cy, keys.rlk), keys.sk)))[0])
    assert add == (x + y) % t
    assert mul == (x * y) % t
