"""Kernel <-> reference parity for the batched limb-op dispatch layer.

Exercises the Pallas `mul_mod/add_mod/sub_mod` and forward/inverse NTT
kernels (interpret mode on CPU) against the pure-jnp `*_ref` oracles
through `core/limbops.LimbOps`, across several limb counts, batch
shapes, non-tile-aligned lengths, and edge values (0, q-1).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.limbops import LimbOps, pallas_supported, resolve_backend
from repro.core.mathutil import find_ntt_primes
from repro.core.params import make_params

POINTWISE = ("mul", "add", "sub")


def _rand(rng, primes, shape_prefix, n):
    k = len(primes)
    return jnp.asarray(
        rng.integers(0, np.array(primes)[:, None], shape_prefix + (k, n)))


@pytest.fixture(scope="module")
def param_grid():
    """(params, ref LimbOps, pallas LimbOps) for several (n, t, k)."""
    out = []
    for n, t, k in [(64, 257, 1), (128, 257, 2), (256, 7681, 3)]:
        p = make_params(n=n, t=t, k=k)
        out.append((p,
                    LimbOps(p.Q, backend="ref"),
                    LimbOps(p.Q, backend="pallas", interpret=True)))
    return out


def test_pallas_backend_resolves(param_grid):
    for p, _, pal in param_grid:
        assert pal.backend == "pallas", p.Q.primes


@pytest.mark.parametrize("op", POINTWISE)
def test_pointwise_parity(param_grid, op):
    rng = np.random.default_rng(7)
    for p, ref, pal in param_grid:
        a = _rand(rng, p.Q.primes, (), p.n)
        b = _rand(rng, p.Q.primes, (), p.n)
        got = getattr(pal, op)(a, b)
        exp = getattr(ref, op)(a, b)
        assert np.array_equal(np.asarray(got), np.asarray(exp)), (op, p.n)


@pytest.mark.parametrize("op", POINTWISE)
@pytest.mark.parametrize("batch", [(2,), (3, 2)])
def test_pointwise_parity_batched(param_grid, op, batch):
    """Batched (.., k, n) inputs match both the ref and the per-slice loop."""
    rng = np.random.default_rng(11)
    p, ref, pal = param_grid[-1]
    a = _rand(rng, p.Q.primes, batch, p.n)
    b = _rand(rng, p.Q.primes, batch, p.n)
    got = np.asarray(getattr(pal, op)(a, b))
    exp = np.asarray(getattr(ref, op)(a, b))
    assert np.array_equal(got, exp)
    flat_a = a.reshape((-1,) + a.shape[-2:])
    flat_b = b.reshape((-1,) + b.shape[-2:])
    loop = np.stack([np.asarray(getattr(pal, op)(x, y))
                     for x, y in zip(flat_a, flat_b)])
    assert np.array_equal(got.reshape(loop.shape), loop)


def test_pointwise_edge_values(param_grid):
    """0 and q-1 lanes: the Barrett/csub corner cases."""
    for p, ref, pal in param_grid:
        k, n = len(p.Q.primes), p.n
        qcol = np.array(p.Q.primes, dtype=np.int64)[:, None]
        zeros = jnp.zeros((k, n), dtype=jnp.int64)
        qm1 = jnp.asarray(np.broadcast_to(qcol - 1, (k, n)).copy())
        for a, b in [(zeros, zeros), (zeros, qm1), (qm1, zeros), (qm1, qm1)]:
            for op in POINTWISE:
                got = getattr(pal, op)(a, b)
                exp = getattr(ref, op)(a, b)
                assert np.array_equal(np.asarray(got), np.asarray(exp)), op
        # (q-1)^2 is the largest Barrett product
        exp_mul = np.asarray((np.asarray(qm1) * np.asarray(qm1)) % qcol)
        assert np.array_equal(np.asarray(pal.mul(qm1, qm1)), exp_mul)


def test_pointwise_non_tile_aligned():
    """Column tiles that do not divide n: the grid's ragged last tile."""
    from repro.kernels.modops.modops import add_mod_pallas, mul_mod_pallas, sub_mod_pallas
    from repro.kernels.modops import ref as mod_ref
    from repro.kernels.u32 import barrett_precompute
    n, rows = 384, 3           # 384 = 3 x 128: not a power of two
    primes = find_ntt_primes(64, 30, rows)
    q64 = jnp.asarray(np.array(primes, dtype=np.int64))
    qu = jnp.asarray(np.array(primes, dtype=np.uint32))[:, None]
    mu = jnp.asarray(np.array([barrett_precompute(q) for q in primes],
                              dtype=np.uint32))[:, None]
    rng = np.random.default_rng(5)
    a = rng.integers(0, np.array(primes)[:, None], (rows, n))
    b = rng.integers(0, np.array(primes)[:, None], (rows, n))
    au, bu = jnp.asarray(a, dtype=jnp.uint32), jnp.asarray(b, dtype=jnp.uint32)
    ai, bi = jnp.asarray(a), jnp.asarray(b)
    for tile in (256, 96):     # 384 % 256 != 0; 384 % 96 == 0
        got = mul_mod_pallas(au, bu, qu, mu, tile=tile).astype(jnp.int64)
        assert np.array_equal(np.asarray(got),
                              np.asarray(mod_ref.mul_mod_ref(ai, bi, q64))), tile
        got = add_mod_pallas(au, bu, qu, tile=tile).astype(jnp.int64)
        assert np.array_equal(np.asarray(got),
                              np.asarray(mod_ref.add_mod_ref(ai, bi, q64))), tile
        got = sub_mod_pallas(au, bu, qu, tile=tile).astype(jnp.int64)
        assert np.array_equal(np.asarray(got),
                              np.asarray(mod_ref.sub_mod_ref(ai, bi, q64))), tile


@pytest.mark.parametrize("batch", [(), (2,), (4,)])
def test_ntt_roundtrip_parity(param_grid, batch):
    rng = np.random.default_rng(13)
    for p, ref, pal in param_grid:
        a = _rand(rng, p.Q.primes, batch, p.n)
        fwd_p, fwd_r = pal.ntt(a), ref.ntt(a)
        assert np.array_equal(np.asarray(fwd_p), np.asarray(fwd_r)), p.n
        inv_p, inv_r = pal.intt(fwd_p), ref.intt(fwd_r)
        assert np.array_equal(np.asarray(inv_p), np.asarray(inv_r))
        assert np.array_equal(np.asarray(inv_p), np.asarray(a))


def test_ntt_edge_values(param_grid):
    for p, ref, pal in param_grid[:1]:
        k, n = len(p.Q.primes), p.n
        qcol = np.array(p.Q.primes, dtype=np.int64)[:, None]
        for arr in (np.zeros((k, n), dtype=np.int64),
                    np.broadcast_to(qcol - 1, (k, n)).copy()):
            a = jnp.asarray(arr)
            assert np.array_equal(np.asarray(pal.ntt(a)), np.asarray(ref.ntt(a)))
            assert np.array_equal(np.asarray(pal.intt(a)), np.asarray(ref.intt(a)))


def test_aux_base_falls_back_to_ref():
    """31-bit HPS auxiliary primes sit outside the Barrett window."""
    p = make_params(n=64, t=257, k=1)
    assert not pallas_supported(p.P.primes)
    assert LimbOps(p.P, backend="pallas").backend == "ref"
    assert LimbOps(p.Q, backend="pallas").backend == "pallas"


def test_resolve_backend_flags():
    primes_ok = find_ntt_primes(64, 30, 2)
    assert resolve_backend("ref", primes_ok) == "ref"
    assert resolve_backend("pallas", primes_ok) == "pallas"
    assert resolve_backend("auto", primes_ok) in ("ref", "pallas")
    with pytest.raises(ValueError):
        resolve_backend("cuda", primes_ok)
