"""Number theory + NTT reference correctness (unit + hypothesis)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import mathutil as mu
from repro.core import ntt as nttm
from repro.core.params import make_params


@given(st.integers(2, 10**6))
@settings(max_examples=200, deadline=None)
def test_is_prime_matches_trial_division(n):
    def trial(n):
        if n < 2:
            return False
        d = 2
        while d * d <= n:
            if n % d == 0:
                return False
            d += 1
        return True
    assert mu.is_prime(n) == trial(n)


@given(st.integers(1, 10**9), st.sampled_from([257, 7681, 65537, 786433]))
@settings(max_examples=100, deadline=None)
def test_modinv(a, p):
    if a % p == 0:
        return
    assert a * mu.modinv(a, p) % p == 1


def test_find_ntt_primes():
    primes = mu.find_ntt_primes(256, 30, 5)
    assert len(set(primes)) == 5
    for q in primes:
        assert mu.is_prime(q) and (q - 1) % 512 == 0 and q < 2**30


@given(st.lists(st.integers(0, 2**29), min_size=3, max_size=3))
@settings(max_examples=50, deadline=None)
def test_crt_roundtrip(rs):
    mods = [2**30 - 35, 2**30 - 77, 2**30 - 41]  # any coprime triple works
    rs = [r % m for r, m in zip(rs, mods)]
    X = mu.crt_reconstruct(rs, mods)
    for r, m in zip(rs, mods):
        assert X % m == r


@pytest.mark.parametrize("n", [64, 256])
def test_ntt_matches_naive_negacyclic(n):
    p = make_params(n=n, t=257 if n <= 128 else 7681, k=2)
    rng = np.random.default_rng(0)
    q = p.Q.primes[0]
    a = rng.integers(0, q, n)
    b = rng.integers(0, q, n)
    import jax.numpy as jnp
    tabs = p.Q
    got = nttm.polymul_ref(jnp.asarray(a[None, :]), jnp.asarray(b[None, :]),
                           type("T", (), {"psi_rev": jnp.asarray(tabs.psi_rev[:1]),
                                          "ipsi_rev": jnp.asarray(tabs.ipsi_rev[:1]),
                                          "n_inv": jnp.asarray(tabs.n_inv[:1]),
                                          "q": jnp.asarray(tabs.q[:1])}))
    exp = nttm.negacyclic_naive(a, b, q)
    assert np.array_equal(np.asarray(got)[0], exp)


def test_ntt_roundtrip_all_limbs():
    p = make_params(n=256, t=7681, k=3)
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, np.array(p.Q.primes)[:, None], (p.k, p.n)))
    f = nttm.ntt_ref(a, jnp.asarray(p.Q.psi_rev), jnp.asarray(p.Q.q))
    back = nttm.intt_ref(f, jnp.asarray(p.Q.ipsi_rev), jnp.asarray(p.Q.n_inv),
                         jnp.asarray(p.Q.q))
    assert np.array_equal(np.asarray(back), np.asarray(a))
