"""Batched column evaluation vs the per-block path: bit-identical results.

The batched API (CiphertextBatch / stacked MockCipher, engine/ops
column-at-a-time operators) must decrypt to exactly what the per-block
Python loop produces, with identical OpStats and noise accounting —
that is what makes the kernel/batching swap safe to land.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import compare as cmp
from repro.core.noise import NoiseProfile
from repro.engine import ops
from repro.engine.backend import BFVBackend, MockBackend
from repro.engine.schema import ColumnSpec, TableSchema
from repro.engine.storage import Database


# ---------------------------------------------------------------------------
# BFVContext-level: batched ops vs per-block loops on real ciphertexts.
# ---------------------------------------------------------------------------

def _blocks(bk, rng, nb):
    return [bk.encrypt(rng.integers(0, bk.t, bk.slots)) for _ in range(nb)]


def test_context_batched_ops_match_looped(bfv_micro):
    bk = bfv_micro
    ctx, keys = bk.ctx, bk.keys
    rng = np.random.default_rng(0)
    xs = _blocks(bk, rng, 3)
    ys = _blocks(bk, rng, 3)

    pairs = [
        (ctx.add_many(xs, ys), [ctx.add(a, b) for a, b in zip(xs, ys)]),
        (ctx.sub_many(xs, ys), [ctx.sub(a, b) for a, b in zip(xs, ys)]),
        (ctx.mul_many(xs, ys, keys.rlk),
         [ctx.mul(a, b, keys.rlk) for a, b in zip(xs, ys)]),
    ]
    m_poly = bk.enc.encode(rng.integers(0, bk.t, bk.slots))
    pairs.append((ctx.mul_plain_many(xs, m_poly),
                  [ctx.mul_plain(a, m_poly) for a in xs]))
    pairs.append((ctx.rotate_rows_many(xs, 3, keys.gks),
                  [ctx.rotate_rows(a, 3, keys.gks) for a in xs]))
    pairs.append((ctx.sum_slots_many(xs, keys.gks),
                  [ctx.sum_slots(a, keys.gks) for a in xs]))
    for batched, looped in pairs:
        for b, l in zip(batched, looped):
            assert np.array_equal(np.asarray(b.data), np.asarray(l.data))
            assert b.noise == pytest.approx(l.noise)


def test_backend_stack_fold_roundtrip(bfv_micro):
    bk = bfv_micro
    rng = np.random.default_rng(1)
    xs = _blocks(bk, rng, 4)
    batch = bk.stack_blocks(xs)
    back = bk.unstack_blocks(batch)
    for a, b in zip(xs, back):
        assert np.array_equal(np.asarray(a.data), np.asarray(b.data))

    bk.stats.reset()
    folded = bk.fold_blocks(bk.stack_blocks(xs))
    adds_batched = bk.stats.add
    bk.stats.reset()
    acc = xs[0]
    for x in xs[1:]:
        acc = bk.add(acc, x)
    assert adds_batched == bk.stats.add == len(xs) - 1
    assert np.array_equal(np.asarray(folded.data), np.asarray(acc.data))
    assert folded.noise == pytest.approx(acc.noise)


def test_masked_scan_sum_decrypt_equivalence(bfv_micro):
    """encrypt -> masked scan (EQ) -> sum_slots -> decrypt: the batched
    column pipeline decrypts bit-identically to the per-block path and
    charges the exact same OpStats."""
    bk = bfv_micro
    t, S = bk.t, bk.slots
    rng = np.random.default_rng(2)
    raw = [rng.integers(0, 5, S) for _ in range(3)]
    vals = [rng.integers(0, 16, S) for _ in range(3)]

    # -- per-block reference path ------------------------------------
    col = [bk.encrypt(r) for r in raw]
    vcol = [bk.encrypt(v) for v in vals]
    bk.stats.reset()
    mask_l = [cmp.eq_scalar(bk, ct, 3) for ct in col]
    filt_l = [bk.mul(c, m) for c, m in zip(vcol, mask_l)]
    acc = filt_l[0]
    for b in filt_l[1:]:
        acc = bk.add(acc, b)
    total_l = bk.sum_slots(acc)
    stats_l = bk.stats.clone()
    dec_l = bk.decrypt(total_l)

    # -- batched path -------------------------------------------------
    col = [bk.encrypt(r) for r in raw]
    vcol = [bk.encrypt(v) for v in vals]
    bk.stats.reset()
    x = bk.stack_blocks(col)
    mask_b = bk.unstack_blocks(cmp.eq_scalar(bk, x, 3))
    filt_b = ops.mask_columns(bk, vcol, mask_b)
    total_b = ops.reduce_blocks(bk, filt_b)
    stats_b = bk.stats.clone()
    dec_b = bk.decrypt(total_b)

    expected = sum(int((r == 3).astype(np.int64) @ v) for r, v in zip(raw, vals)) % t
    assert np.array_equal(dec_l, dec_b)
    assert int(dec_b[0]) == expected
    assert total_b.noise == pytest.approx(total_l.noise)
    # decrypt/encrypt counters differ by bookkeeping order only — compare ops
    for f in ("mul", "mul_plain", "mul_scalar", "add", "rotate", "refresh", "max_depth"):
        assert getattr(stats_b, f) == getattr(stats_l, f), f


# ---------------------------------------------------------------------------
# MockBackend: batched == looped on a multi-block encrypted table.
# ---------------------------------------------------------------------------

def _mock_db(nrows=600, slots=256, kernel_reduce=False):
    bk = MockBackend(NoiseProfile(n=slots, t=65537, k=30),
                     kernel_reduce=kernel_reduce)
    schema = TableSchema("items", [
        ColumnSpec("grp", "int"),
        ColumnSpec("qty", "int"),
    ])
    rng = np.random.default_rng(4)
    data = {"grp": rng.integers(1, 6, nrows), "qty": rng.integers(0, 50, nrows)}
    db = Database(bk)
    db.load_table(schema, data, nrows)
    return bk, db


def test_engine_ops_batched_multiblock_table():
    """pred_mask/and_masks/masked_sum over a 3-block column vs both the
    plaintext oracle and an explicit per-block loop with its OpStats."""
    from repro.engine.plan import Pred
    bk, db = _mock_db()
    tbl = db.tables["items"]
    plain = db.plain["items"]
    assert tbl.nblocks == 3

    bk.stats.reset()
    m1 = ops.pred_mask(bk, tbl, Pred("grp", "=", 2))
    m2 = ops.pred_mask(bk, tbl, Pred("qty", "<", 25))
    both = ops.and_masks(bk, [m1, m2])
    both = ops.apply_validity(bk, both, tbl)
    total = ops.masked_sum(bk, tbl.col("qty").blocks, both)
    cnt = ops.count(bk, both)
    stats_b = bk.stats.clone()

    sel = (plain["grp"] == 2) & (plain["qty"] < 25)
    assert int(bk.decrypt(total)[0]) == int(plain["qty"][sel].sum()) % bk.t
    assert int(bk.decrypt(cnt)[0]) == int(sel.sum())

    # explicit per-block loop (the pre-batching operator semantics)
    bk.stats.reset()
    blocks_g = tbl.col("grp").blocks
    blocks_q = tbl.col("qty").blocks
    m1_l = [cmp.eq_scalar(bk, ct, 2) for ct in blocks_g]
    m2_l = [cmp.lt_scalar(bk, ct, 25) for ct in blocks_q]
    both_l = [cmp.mul_tree(bk, [a, b]) for a, b in zip(m1_l, m2_l)]
    both_l = ops.apply_validity(bk, both_l, tbl)
    filt = [bk.mul(c, m) for c, m in zip(blocks_q, both_l)]
    acc = filt[0]
    for b in filt[1:]:
        acc = bk.add(acc, b)
    total_l = bk.sum_slots(acc)
    acc = both_l[0]
    for b in both_l[1:]:
        acc = bk.add(acc, b)
    cnt_l = bk.sum_slots(acc)
    stats_l = bk.stats.clone()

    assert np.array_equal(bk.decrypt(total), bk.decrypt(total_l))
    assert np.array_equal(bk.decrypt(cnt), bk.decrypt(cnt_l))
    # apply_validity leaves the last block noisier than the rest; stacking
    # tracks the max, so the batched bound is conservative (never lower).
    assert total.noise >= total_l.noise - 1e-9
    assert total.noise <= total_l.noise + 4.0
    # launches differ by design (batching = fewer primitive calls for the
    # same charged work); every charged counter must match exactly.
    assert _charged(stats_b) == _charged(stats_l)
    assert stats_b.launches < stats_l.launches


def _charged(stats):
    """OpStats minus the schedule-dependent launch counter."""
    d = dataclasses.asdict(stats)
    d.pop("launches")
    return d


def test_mock_kernel_reduce_matches_looped():
    """sum_slots via the Pallas rotate-reduce kernel: identical slots,
    noise, and OpStats as the rotate+add loop."""
    bk_loop, db_loop = _mock_db(kernel_reduce=False)
    bk_kern, db_kern = _mock_db(kernel_reduce=True)
    for bk, db in ((bk_loop, db_loop), (bk_kern, db_kern)):
        bk.stats.reset()
    x_l = bk_loop.encrypt(np.arange(200) % bk_loop.t)
    x_k = bk_kern.encrypt(np.arange(200) % bk_kern.t)
    s_l = bk_loop.sum_slots(x_l)
    s_k = bk_kern.sum_slots(x_k)
    assert np.array_equal(s_l.vec, s_k.vec)
    assert s_l.noise == pytest.approx(s_k.noise)
    assert _charged(bk_loop.stats) == _charged(bk_kern.stats)
    # batched form
    cols_l = bk_loop.stack_blocks([bk_loop.encrypt(np.full(256, i)) for i in (1, 2, 3)])
    cols_k = bk_kern.stack_blocks([bk_kern.encrypt(np.full(256, i)) for i in (1, 2, 3)])
    r_l, r_k = bk_loop.sum_slots(cols_l), bk_kern.sum_slots(cols_k)
    assert np.array_equal(r_l.vec, r_k.vec)
    assert r_k.vec.shape == (3, 256)
    assert np.array_equal(r_k.vec[:, 0], np.array([256, 512, 768]) % bk_kern.t)


def test_mock_mixed_single_batch_broadcast():
    bk, db = _mock_db()
    tbl = db.tables["items"]
    batch = bk.stack_blocks(tbl.col("qty").blocks)
    single = bk.encrypt(np.full(256, 2))
    prod = bk.mul(batch, single)
    assert prod.vec.shape == batch.vec.shape
    for i, blk in enumerate(tbl.col("qty").blocks):
        assert np.array_equal(prod.vec[i], (blk.vec * 2) % bk.t)


def test_bfv_backend_kernel_flag_matches_ref():
    """A BFVBackend on the Pallas limb path decrypts identically to ref."""
    from repro.core.params import make_params
    p = make_params(n=128, t=257, k=2)
    ref = BFVBackend(p, seed=3, kernel_backend="ref")
    pal = BFVBackend(p, seed=3, kernel_backend="pallas", interpret=True)
    assert pal.ctx.limb_q.backend == "pallas"
    v = np.arange(128) % 257
    cr, cp = ref.encrypt(v), pal.encrypt(v)
    assert np.array_equal(np.asarray(cr.data), np.asarray(cp.data))
    mr = ref.mul(cr, ref.encrypt(v))
    mp = pal.mul(cp, pal.encrypt(v))
    assert np.array_equal(ref.decrypt(mr), pal.decrypt(mp))
    assert np.array_equal(ref.decrypt(mr), (v * v) % 257)
