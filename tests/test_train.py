"""Training loop: AdamW numerics, loss decreases on learnable data,
checkpoint-resume continuity."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train.optim import adamw_init, adamw_update


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, opt = adamw_update(g, opt, params, lr=5e-2, wd=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.asarray([10.0])}
    opt = adamw_init(params)
    for _ in range(50):
        params, opt = adamw_update({"w": jnp.zeros(1)}, opt, params,
                                   lr=1e-2, wd=0.5)
    assert float(params["w"][0]) < 10.0


@pytest.mark.slow
def test_loss_decreases_on_structured_stream():
    pytest.importorskip("repro.dist.sharding")  # launch.train depends on it
    from repro.launch.train import main
    losses = main(["--arch", "starcoder2-3b", "--smoke", "--steps", "80",
                   "--batch", "8", "--seq", "32", "--lr", "3e-3",
                   "--log-every", "40"])
    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_checkpoint_resume_continuity(tmp_path):
    """Train 20 steps, checkpoint, resume for 10 more: the resumed loss
    sequence must equal an uninterrupted 30-step run's tail."""
    pytest.importorskip("repro.dist.sharding")  # launch.train depends on it
    from repro.launch.train import main
    args = ["--arch", "qwen2-72b", "--smoke", "--batch", "4", "--seq", "16",
            "--lr", "1e-3", "--log-every", "100"]
    full = main(args + ["--steps", "30"])
    d1 = str(tmp_path / "ck")
    main(args + ["--steps", "20", "--ckpt-dir", d1, "--ckpt-every", "20"])
    resumed = main(args + ["--steps", "30", "--ckpt-dir", d1,
                           "--ckpt-every", "100", "--resume"])
    np.testing.assert_allclose(resumed, full[20:], rtol=1e-4, atol=1e-5)
