"""Comparison circuits: exhaustive on the mock backend, spot-checked on
real ciphertexts, plus hypothesis properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import compare as cmp
from repro.core.noise import NoiseProfile
from repro.engine.backend import BFVBackend, MockBackend


def centered(z, p):
    z = z % p
    return z - p if z > p // 2 else z


@pytest.fixture(scope="module")
def mk257():
    return MockBackend(NoiseProfile(n=512, t=257, k=12))


def test_eq_exhaustive_mock(mk257):
    p = mk257.t
    zs = np.arange(p)
    x = mk257.encrypt(zs)
    for c in (0, 1, 128, 255):
        got = mk257.decrypt(cmp.eq_scalar(mk257, x, c))[:p]
        assert np.array_equal(got, (zs == c).astype(int)), c


def test_lt_gt_le_ge_exhaustive_mock(mk257):
    p = mk257.t
    zs = np.arange(p)
    x = mk257.encrypt(zs)
    c = 100
    cent = np.array([centered(z - c, p) for z in zs])
    assert np.array_equal(mk257.decrypt(cmp.lt_scalar(mk257, x, c))[:p],
                          (cent < 0).astype(int))
    assert np.array_equal(mk257.decrypt(cmp.gt_scalar(mk257, x, c))[:p],
                          (cent > 0).astype(int))
    assert np.array_equal(mk257.decrypt(cmp.ge_scalar(mk257, x, c))[:p],
                          (cent >= 0).astype(int))
    assert np.array_equal(mk257.decrypt(cmp.le_scalar(mk257, x, c))[:p],
                          (cent <= 0).astype(int))


def test_between_in_and_bool_algebra(mk257):
    p = mk257.t
    zs = np.arange(p)
    x = mk257.encrypt(zs)
    got = mk257.decrypt(cmp.between_scalar(mk257, x, 10, 20))[:p]
    assert np.array_equal(got, ((zs >= 10) & (zs <= 20)).astype(int))
    got = mk257.decrypt(cmp.in_set(mk257, x, [1, 5, 77]))[:p]
    assert np.array_equal(got, np.isin(zs, [1, 5, 77]).astype(int))
    a = cmp.eq_scalar(mk257, x, 5)
    b = cmp.eq_scalar(mk257, x, 7)
    assert np.array_equal(mk257.decrypt(cmp.or_(mk257, a, b))[:p],
                          np.isin(zs, [5, 7]).astype(int))
    assert np.array_equal(mk257.decrypt(cmp.not_(mk257, a))[:p],
                          (zs != 5).astype(int))


def test_lt_depth_matches_table3(mk257):
    """Table 3: comparison depth = ceil(log2(p-1)) + O(1)."""
    import math
    x = mk257.encrypt(np.arange(10))
    lt = cmp.lt_scalar(mk257, x, 5)
    eq_d = math.ceil(math.log2(mk257.t - 1))
    assert lt.depth <= eq_d + 2


def test_eq_lt_on_real_ciphertexts(bfv_micro):
    bk = bfv_micro
    vals = np.array([0, 1, 42, 99, 100, 101, 128, 200, 256])
    x = bk.encrypt(vals)
    assert np.array_equal(bk.decrypt(cmp.eq_scalar(bk, x, 42))[:9],
                          (vals == 42).astype(int))
    cent = np.array([centered(v - 100, 257) for v in vals])
    assert np.array_equal(bk.decrypt(cmp.lt_scalar(bk, x, 100))[:9],
                          (cent < 0).astype(int))
    assert bk.stats.refresh == 0, "micro params must fit the LT circuit"


def test_pow_ct_generic_exponent(mk257):
    """Square-and-multiply path for non-power-of-two exponents."""
    x = mk257.encrypt(np.arange(1, 20))
    got = mk257.decrypt(cmp.pow_ct(mk257, x, 13))[:19]
    exp = np.array([pow(int(v), 13, 257) for v in range(1, 20)])
    assert np.array_equal(got, exp)


@given(st.integers(0, 65536), st.integers(0, 65536))
@settings(max_examples=20, deadline=None)
def test_eq_property_paper_modulus(x, y):
    bk = MockBackend()          # t = 65537
    cx = bk.encrypt(np.array([x]))
    got = int(bk.decrypt(cmp.eq_scalar(bk, cx, y))[0])
    assert got == int(x == y)


@given(st.integers(0, 32000), st.integers(0, 32000))
@settings(max_examples=10, deadline=None)
def test_lt_property_paper_modulus(x, y):
    bk = MockBackend()
    cx = bk.encrypt(np.array([x]))
    got = int(bk.decrypt(cmp.lt_scalar(bk, cx, y))[0])
    assert got == int(x < y), (x, y)
