"""Hypothesis property tests on system-level invariants.

The engine's correctness rests on a few algebraic facts about encrypted
{0,1} masks and the homomorphism — these check them on randomized data
rather than fixed fixtures.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import compare as cmp
from repro.core.noise import NoiseProfile
from repro.engine.backend import MockBackend

small_vecs = st.lists(st.integers(0, 100), min_size=4, max_size=24)


def _bk():
    return MockBackend(NoiseProfile(n=256, t=257, k=12))


@given(small_vecs, st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_mask_idempotent(vals, c):
    """Masks are {0,1}: m*m == m — the reason re-ANDing filters in the
    unoptimized pipeline stays correct."""
    bk = _bk()
    x = bk.encrypt(np.array(vals))
    m = cmp.eq_scalar(bk, x, c)
    mm = bk.mul(m, m)
    assert np.array_equal(bk.decrypt(m), bk.decrypt(mm))


@given(small_vecs, st.integers(0, 100), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_de_morgan(vals, a, b):
    """NOT(x AND y) == NOT(x) OR NOT(y) over encrypted masks."""
    bk = _bk()
    x = bk.encrypt(np.array(vals))
    mx = cmp.eq_scalar(bk, x, a)
    my = cmp.lt_scalar(bk, x, b % 50)
    lhs = cmp.not_(bk, cmp.and_(bk, mx, my))
    rhs = cmp.or_(bk, cmp.not_(bk, mx), cmp.not_(bk, my))
    assert np.array_equal(bk.decrypt(lhs), bk.decrypt(rhs))


@given(small_vecs, st.integers(1, 50))
@settings(max_examples=25, deadline=None)
def test_trichotomy(vals, c):
    """LT + EQ + GT == 1 for every slot (the sgn decomposition's core)."""
    bk = _bk()
    arr = np.array(vals)
    x = bk.encrypt(arr)
    total = bk.add(bk.add(cmp.lt_scalar(bk, x, c), cmp.eq_scalar(bk, x, c)),
                   cmp.gt_scalar(bk, x, c))
    assert np.all(bk.decrypt(total)[: len(vals)] == 1)


@given(small_vecs, st.integers(0, 60), st.integers(0, 60))
@settings(max_examples=20, deadline=None)
def test_select_sum_linearity(vals, lo, hi):
    """SUM over (A or B) + SUM over (A and B) == SUM over A + SUM over B
    — inclusion/exclusion survives the encrypted masks + aggregation."""
    bk = _bk()
    lo, hi = min(lo, hi), max(lo, hi)
    arr = np.array(vals)
    x = bk.encrypt(arr)
    v = bk.encrypt(arr)  # aggregate the values themselves
    a = cmp.lt_scalar(bk, x, hi + 1)
    b = cmp.ge_scalar(bk, x, lo)
    union = cmp.or_(bk, a, b)
    inter = cmp.and_(bk, a, b)
    s = lambda m: int(bk.decrypt(bk.sum_slots(bk.mul(v, m)))[0])
    assert (s(union) + s(inter)) % bk.t == (s(a) + s(b)) % bk.t


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_q6_style_query_randomized(seed):
    """A Q6-shaped query on random data always matches plain numpy."""
    bk = _bk()
    rng = np.random.default_rng(seed)
    n = 32
    day = rng.integers(1, 101, n)
    price = rng.integers(1, 101, n)
    qty = rng.integers(1, 11, n)
    cd, cq = int(rng.integers(2, 99)), int(rng.integers(2, 10))
    xd, xp, xq = bk.encrypt(day), bk.encrypt(price), bk.encrypt(qty)
    mask = cmp.and_(bk, cmp.lt_scalar(bk, xd, cd), cmp.ge_scalar(bk, xq, cq))
    got = int(bk.decrypt(bk.sum_slots(bk.mul(xp, mask)))[0])
    exp = int(price[(day < cd) & (qty >= cq)].sum()) % bk.t
    assert got == exp


@given(st.lists(st.integers(0, 256), min_size=2, max_size=16))
@settings(max_examples=25, deadline=None)
def test_rotate_then_sum_invariant(vals):
    """sum_slots is rotation-invariant: aggregating a rotated column
    gives the same total (the scan-first architecture's degree of
    freedom in data placement)."""
    bk = _bk()
    x = bk.encrypt(np.array(vals))
    s1 = int(bk.decrypt(bk.sum_slots(x))[0])
    s2 = int(bk.decrypt(bk.sum_slots(bk.rotate(x, 3)))[0])
    assert s1 == s2 == int(np.sum(vals)) % bk.t
