"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode + uint32 modular arithmetic properties (hypothesis)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.params import make_params
from repro.kernels import u32

PRIME30 = 1073479681  # 30-bit NTT prime


@given(st.integers(0, PRIME30 - 1), st.integers(0, PRIME30 - 1))
@settings(max_examples=200, deadline=None)
def test_barrett_mulmod_property(a, b):
    mu = u32.barrett_precompute(PRIME30)
    got = int(u32.barrett_mulmod(jnp.uint32(a), jnp.uint32(b),
                                 jnp.uint32(PRIME30), jnp.uint32(mu)))
    assert got == a * b % PRIME30


@given(st.integers(0, PRIME30 - 1), st.integers(1, PRIME30 - 1))
@settings(max_examples=200, deadline=None)
def test_shoup_mulmod_property(a, w):
    ws = u32.shoup_precompute(w, PRIME30)
    got = int(u32.shoup_mulmod(jnp.uint32(a), jnp.uint32(w),
                               jnp.uint32(ws), jnp.uint32(PRIME30)))
    assert got == a * w % PRIME30


@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_mulhi_property(a, b):
    got = int(u32.mulhi_u32(jnp.uint32(a), jnp.uint32(b)))
    assert got == (a * b) >> 32


@pytest.mark.parametrize("n,k", [(64, 1), (128, 2), (256, 3), (512, 2)])
def test_ntt_kernel_sweep(n, k):
    from repro.kernels.ntt import ops as ntt_ops
    from repro.kernels.ntt import ref as ntt_ref
    t = {64: 257, 128: 257, 256: 7681, 512: 12289}[n]
    p = make_params(n=n, t=t, k=k)
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.integers(0, np.array(p.Q.primes)[:, None], (k, n)))
    got = ntt_ops.ntt_fwd(a, p.Q)
    exp = ntt_ref.ntt_fwd_ref(a, jnp.asarray(p.Q.psi_rev), jnp.asarray(p.Q.q))
    assert np.array_equal(np.asarray(got), np.asarray(exp))
    back = ntt_ops.ntt_inv(got, p.Q)
    assert np.array_equal(np.asarray(back), np.asarray(a))


@pytest.mark.parametrize("rows,n", [(1, 128), (3, 256), (6, 512)])
def test_modops_kernel_sweep(rows, n):
    from repro.core.mathutil import find_ntt_primes
    from repro.kernels.modops import ops as mod_ops
    from repro.kernels.modops import ref as mod_ref
    primes = tuple(find_ntt_primes(n, 30, rows))
    q = jnp.asarray(np.array(primes, dtype=np.int64))
    rng = np.random.default_rng(rows * n)
    a = jnp.asarray(rng.integers(0, np.array(primes)[:, None], (rows, n)))
    b = jnp.asarray(rng.integers(0, np.array(primes)[:, None], (rows, n)))
    for op, ref in [(mod_ops.mul_mod, mod_ref.mul_mod_ref),
                    (mod_ops.add_mod, mod_ref.add_mod_ref),
                    (mod_ops.sub_mod, mod_ref.sub_mod_ref)]:
        got = op(a, b, primes)
        assert np.array_equal(np.asarray(got), np.asarray(ref(a, b, q)))


@pytest.mark.parametrize("rows,n,chunk", [(2, 256, None), (4, 1024, None),
                                          (3, 512, 8)])
def test_rotate_reduce_sweep(rows, n, chunk):
    from repro.kernels.rotate_reduce import ops as rr_ops
    from repro.kernels.rotate_reduce import ref as rr_ref
    rng = np.random.default_rng(n)
    x = rng.integers(0, 65537, (rows, n))
    got = rr_ops.rotate_reduce(x, 65537, chunk=chunk)
    exp = rr_ref.rotate_reduce_ref(jnp.asarray(x, dtype=jnp.int32), 65537,
                                   chunk=chunk)
    assert np.array_equal(np.asarray(got), np.asarray(exp))
    if chunk is None:
        assert int(np.asarray(got)[0, 0]) == int(x[0].sum() % 65537)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kwargs", [dict(causal=True),
                                    dict(causal=True, window=32),
                                    dict(causal=True, softcap=50.0),
                                    dict(causal=False)])
def test_flash_attention_sweep(dtype, kwargs):
    from repro.kernels.flash_attn import ops as fa_ops
    from repro.kernels.flash_attn.ref import attention_ref
    B, H, Hkv, S, D = 2, 4, 2, 128, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, D), dtype)
    got = fa_ops.mha(q, k, v, **kwargs)
    kr = jnp.repeat(k, H // Hkv, axis=1).reshape(B * H, S, D)
    vr = jnp.repeat(v, H // Hkv, axis=1).reshape(B * H, S, D)
    exp = attention_ref(q.reshape(B * H, S, D), kr, vr, **kwargs)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.abs(got.astype(jnp.float32)
                        - exp.reshape(B, H, S, D).astype(jnp.float32)).max())
    assert err < tol, err
