"""2-D mesh execution: RNS limbs sharded over the model axis (DESIGN §4).

Parity contract, now in two dimensions: running a compiled QueryPlan at
any (shards, limb_shards) combination must be *byte-identical* to the
single-device path — decrypted results, OpStats, noise trajectories and
refresh schedules all match.  The data axis pads block lanes (PR 7);
the model axis splits the k RNS limbs, runs NTT/pointwise work
limb-local, and all-gathers the centered key-switch digits before the
base-extension fold, preserving the exact summation order.

Covered here:
  * mock 2-D parity on every ported query x (1,1),(4,1),(1,2),(4,2)
  * real RNS-BFV parity with the gathered key-switch (needs >= 2
    devices; CI forces XLA_FLAGS=--xla_force_host_platform_device_count=8)
  * limb-padding invariants when k % limb_shards != 0 (logical-only
    placement, fractional limb factor)
  * the 2-D cost ledger: limb-local vs all-gather byte accounting
  * elastic_limb_plan + per-axis straggler re-sharding (either mesh
    axis shrinks independently; the other is preserved)
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.noise import NoiseProfile
from repro.engine import queries as Q, tpch
from repro.engine.backend import MockBackend
from repro.engine.executor import run_via_plan
from repro.engine.planner import Planner
from repro.engine.sharded import (ShardContext, limb_pad_to,
                                  make_shard_context)
from repro.runtime import faults
from repro.runtime.elastic import StragglerDetector, elastic_limb_plan

from test_sharded_exec import (_bfv_db, _bfv_oracle, _bfv_plans, _same,
                               _stats_dict)

MULTIBLOCK = NoiseProfile(n=64, t=65537, k=30)
COSTS = {"mul": 0.05, "mul_plain": 0.055, "mul_scalar": 0.002,
         "add": 0.0015, "rotate": 0.105, "refresh": 44.0}
GRID = [(1, 1), (4, 1), (1, 2), (4, 2)]

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >= 2 devices (XLA_FLAGS)")


@pytest.fixture(scope="module")
def mock_mb():
    return MockBackend(MULTIBLOCK)


@pytest.fixture(scope="module")
def db_mb(mock_mb):
    return tpch.load(mock_mb, tpch.Scale.tiny())


def _run(db, qname, shards, limb_shards):
    plan = Q.QUERIES[qname][0]()
    pl = (Planner(db, optimized=True, shards=shards, limb_shards=limb_shards)
          if shards is not None else Planner(db, optimized=True))
    db.bk.stats.reset()
    got = run_via_plan(pl, plan)
    stats = _stats_dict(db.bk.stats.clone())
    ledger = pl.shard_ctx.ledger_snapshot() if pl.shard_ctx else None
    return got, stats, ledger


# ---------------------------------------------------------------------------
# 1. Mock 2-D parity grid.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def grid_runs(db_mb):
    out = {}
    for qn in Q.PLAN_EXECUTABLE:
        out[(qn, None)] = _run(db_mb, qn, None, None)
        for s, m in GRID:
            out[(qn, (s, m))] = _run(db_mb, qn, s, m)
    db_mb.bk.stats.reset()
    return out


@pytest.mark.parametrize("cell", GRID)
@pytest.mark.parametrize("qname", Q.PLAN_EXECUTABLE)
def test_mock_2d_parity_decrypt_identical(grid_runs, db_mb, qname, cell):
    base, _, _ = grid_runs[(qname, None)]
    got, _, _ = grid_runs[(qname, cell)]
    _same(base, got)
    _same(got, Q.QUERIES[qname][2](db_mb))


@pytest.mark.parametrize("cell", GRID)
@pytest.mark.parametrize("qname", Q.PLAN_EXECUTABLE)
def test_mock_2d_parity_stats_identical(grid_runs, qname, cell):
    """Neither padding lanes nor gather charges reach OpStats."""
    _, base_stats, _ = grid_runs[(qname, None)]
    _, stats, _ = grid_runs[(qname, cell)]
    assert base_stats == stats


@pytest.mark.parametrize("qname", Q.PLAN_EXECUTABLE)
def test_mock_ledger_gathers_only_with_limb_axis(grid_runs, qname):
    for s, m in GRID:
        _, _, led = grid_runs[(qname, (s, m))]
        assert led["limb_shards"] == m
        if m > 1:
            assert led["gathers"] > 0 and led["gather_bytes"] > 0
            assert led["limb_local_bytes"] > 0
        else:
            assert led["gathers"] == 0 and led["gather_bytes"] == 0


def test_ledger_models_limb_speedup(db_mb):
    """Same query priced at limb_shards 1 vs 2: limb-local work halves,
    the digit gather costs less than it saves."""
    secs = {}
    for m in (1, 2):
        plan = Q.QUERIES["Q6"][0]()
        pl = Planner(db_mb, shards=1, limb_shards=m)
        run_via_plan(pl, plan)
        secs[m] = pl.shard_ctx.modeled_seconds(COSTS)
    assert secs[2] < secs[1]


# ---------------------------------------------------------------------------
# 2. Real RNS-BFV parity with the all-gathered key-switch.
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("pname", ["g1", "j1", "f1"])
def test_bfv_micro_2d_parity(bfv_micro, pname):
    bk = bfv_micro
    db, data, pdata = _bfv_db(bk)
    plan = next(p for p in _bfv_plans() if p.name == pname)
    bk.stats.reset()
    base = run_via_plan(Planner(db), plan)
    base_stats = _stats_dict(bk.stats.clone())
    for s, m in ((1, 2), (2, 2)):
        if s * m > len(jax.devices()):
            continue
        pl = Planner(db, shards=s, limb_shards=m)
        assert pl.shard_ctx.mesh is not None
        assert "model" in pl.shard_ctx.mesh.axis_names
        bk.stats.reset()
        got = run_via_plan(pl, plan)
        _same(base, got)
        assert base_stats == _stats_dict(bk.stats.clone())
        assert pl.shard_ctx.ledger_snapshot()["gather_bytes"] > 0
    _same(base, _bfv_oracle(plan, data, pdata))


# ---------------------------------------------------------------------------
# 3. Limb-padding invariants.
# ---------------------------------------------------------------------------

def test_limb_pad_to():
    assert limb_pad_to(12, 2) == 12
    assert limb_pad_to(12, 4) == 12
    assert limb_pad_to(30, 4) == 32     # k=30 pads to 8 limbs/device
    assert limb_pad_to(30, 7) == 35
    assert limb_pad_to(30, 1) == 30     # M=1: no padding
    assert limb_pad_to(1, 4) == 4


def test_limb_factor_fractional_when_padded():
    # k=30, M=4: each device holds 8 padded limbs, 2 of 32 are dead,
    # so the per-device speedup is 30/8 = 3.75, not 4.
    ctx = ShardContext(1, limb_shards=4, limbs=30, ring_n=64)
    assert ctx.limb_factor() == pytest.approx(30 / 8)
    # divisible: exact M
    assert ShardContext(1, limb_shards=2, limbs=30,
                        ring_n=64).limb_factor() == pytest.approx(2.0)


def test_non_divisible_limbs_get_no_real_mesh():
    """k % M != 0 keeps placement logical-only: the ledger models the
    padded tower but no device mesh is constructed."""
    ctx = make_shard_context(1, limb_shards=4, limbs=30, ring_n=64)
    assert ctx.mesh is None
    assert ctx.limb_shards == 4 and ctx.workers == 4


def test_shard_context_validates_limb_axis():
    with pytest.raises(ValueError):
        ShardContext(1, limb_shards=0)
    with pytest.raises(ValueError):
        ShardContext(0, limb_shards=2)


def test_ledger_bytes_zero_without_geometry():
    """Legacy ShardContext(N) calls (no limbs/ring_n) stay valid: byte
    ledgers are inert, unit ledgers still work."""
    ctx = ShardContext(2, limb_shards=2)
    ctx.record("mul", 4, distributed=True)
    ctx.record_gather(4)
    assert ctx.gathers == 1 and ctx.gather_bytes == 0
    assert ctx.limb_local_bytes == 0


# ---------------------------------------------------------------------------
# 4. Elastic planning + per-axis re-shard.
# ---------------------------------------------------------------------------

def test_elastic_limb_plan_any_survivor_count():
    # no power-of-two constraint: padding absorbs any M'
    plan = elastic_limb_plan(4, [2], limbs=30)
    assert plan["limb_shards"] == 3 and plan["workers"] == [0, 1, 3]
    assert plan["limb_pad"] == 0        # 30 % 3 == 0
    plan = elastic_limb_plan(4, [0, 3], limbs=30)
    assert plan["limb_shards"] == 2 and plan["limb_pad"] == 0
    plan = elastic_limb_plan(7, [0, 1, 2], limbs=30)
    assert plan["limb_shards"] == 4 and plan["limb_pad"] == 2


def test_elastic_limb_plan_all_excluded_raises():
    with pytest.raises(RuntimeError):
        elastic_limb_plan(2, [0, 1])


def test_reshard_axes_independent():
    ctx = make_shard_context(4, limb_shards=2, limbs=30, ring_n=64)
    shrunk_m = ctx.reshard([1], axis="model")
    assert (shrunk_m.shards, shrunk_m.limb_shards) == (4, 1)
    shrunk_d = ctx.reshard([1, 3], axis="data")
    assert (shrunk_d.shards, shrunk_d.limb_shards) == (2, 2)


@pytest.mark.parametrize("grid,slow,shape", [
    # workers flatten as data_row * M + limb_col.  Straggler sets stay a
    # fleet minority so the EWMA median tracks the healthy workers.
    # 2x4 grid: limb column 2 = workers {2, 6} -> model axis 4 -> 3
    ((2, 4), {2: 10.0, 6: 10.0}, (2, 3)),
    # 4x2 grid: data row 3 = workers {6, 7} -> data axis 4 -> 2 (pow2)
    ((4, 2), {6: 10.0, 7: 10.0}, (2, 2)),
])
def test_straggler_excludes_per_axis(db_mb, grid, slow, shape):
    base, _, _ = _run(db_mb, "Q6", None, None)
    pl = Planner(db_mb, optimized=True, shards=grid[0], limb_shards=grid[1])
    det = StragglerDetector(threshold=2.0, patience=2, timeout_s=1e9)
    pl.attach_straggler_detector(det, COSTS)
    with faults.inject(faults.FaultPlan(straggler_slowdown=dict(slow))):
        for _ in range(2):      # strikes reach patience on round 2
            out = run_via_plan(pl, Q.QUERIES["Q6"][0]())
            _same(base, out)
    assert (pl.shard_ctx.shards, pl.shard_ctx.limb_shards) == shape
    db_mb.bk.stats.reset()


def test_straggler_recovery_logs_axis(db_mb):
    from repro.engine.executor import Executor
    pl = Planner(db_mb, optimized=True, shards=2, limb_shards=4)
    det = StragglerDetector(threshold=2.0, patience=1, timeout_s=1e9)
    pl.attach_straggler_detector(det, COSTS)
    ex = Executor(pl)
    with faults.inject(faults.FaultPlan(straggler_slowdown={2: 10.0, 6: 10.0})):
        ex.run(Q.QUERIES["Q6"][0]())
    rec = [r for r in ex.report.recoveries if r["kind"] == "straggler"]
    assert rec and rec[-1]["axis"] == "model"
    assert "2x4->2x3" in rec[-1]["action"]
    db_mb.bk.stats.reset()
