"""Sharded scan execution (engine/sharded.py, DESIGN §4).

Parity contract: running a compiled QueryPlan with `shards=N` must be
*byte-identical* to the single-device path — decrypted results, OpStats,
noise trajectories and refresh schedules all match, because padding
lanes are additive identities the accounting never sees.  Verified on
the mock backend at a multi-block profile (n=64 so tiny lineitem spans
3 blocks and exercises uneven padding) and on real RNS-BFV ciphertexts
(micro domain).

Also covered here: the satellites that ride the sharded path — per-lane
noise vectors (partial refresh), fused broadcast_slots, the bounded
WorkloadCache LRU, and elastic re-sharding after straggler exclusion.
The real shard_map/psum collective runs only when the host exposes >= 2
devices (CI sets XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
import dataclasses
import types

import jax
import numpy as np
import pytest

from repro.core.noise import NoiseProfile
from repro.engine import ops, queries as Q, tpch
from repro.engine.backend import MockBackend
from repro.engine.executor import run_via_plan
from repro.engine.plan import Agg, And, Factor, JoinHop, Pred, QueryPlan, Translated
from repro.engine.planner import Planner
from repro.engine.schema import ColumnSpec, TableSchema
from repro.engine.sharded import (ShardContext, activate, make_shard_context,
                                  pad_to, sharded_fold)
from repro.engine.storage import Database
from repro.engine.workload import WorkloadCache
from repro.launch.mesh import make_scan_mesh
from repro.runtime.elastic import StragglerDetector, elastic_scan_plan

# Paper noise accounting (t=65537, 30 limbs) at 64 slots: tiny lineitem
# (192 rows) becomes 3 blocks, so shards=2 pads 3 -> 4 lanes.
MULTIBLOCK = NoiseProfile(n=64, t=65537, k=30)

COSTS = {"mul": 0.05, "mul_plain": 0.055, "mul_scalar": 0.002,
         "add": 0.0015, "rotate": 0.105, "refresh": 44.0}


@pytest.fixture(scope="module")
def mock_mb():
    return MockBackend(MULTIBLOCK)


@pytest.fixture(scope="module")
def db_mb(mock_mb):
    return tpch.load(mock_mb, tpch.Scale.tiny())


def _stats_dict(stats):
    return dataclasses.asdict(stats)


def _same(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _same(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _same(x, y)
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 1. Mock parity: sharded == single-device on every ported query x regime.
# ---------------------------------------------------------------------------

def _run_plan(db, qname, optimized, shards):
    plan = Q.QUERIES[qname][0]()
    pl = Planner(db, optimized=optimized,
                 shards=shards) if shards else Planner(db, optimized=optimized)
    bk = db.bk
    bk.stats.reset()
    got = run_via_plan(pl, plan)
    stats = bk.stats.clone()
    ledger = pl.shard_ctx.ledger_snapshot() if pl.shard_ctx else None
    return got, stats, ledger


@pytest.fixture(scope="module")
def parity_runs(db_mb):
    """(query, regime) -> single-device + sharded executions."""
    out = {}
    for qn in Q.PLAN_EXECUTABLE:
        for opt in (True, False):
            base, base_stats, _ = _run_plan(db_mb, qn, opt, None)
            shard, shard_stats, ledger = _run_plan(db_mb, qn, opt, 2)
            out[(qn, opt)] = (base, base_stats, shard, shard_stats, ledger)
    db_mb.bk.stats.reset()
    return out


@pytest.mark.parametrize("optimized", [True, False])
@pytest.mark.parametrize("qname", Q.PLAN_EXECUTABLE)
def test_mock_parity_decrypt_identical(parity_runs, db_mb, qname, optimized):
    base, _, shard, _, _ = parity_runs[(qname, optimized)]
    _same(base, shard)
    # and both still match the plaintext oracle
    _same(shard, Q.QUERIES[qname][2](db_mb))


@pytest.mark.parametrize("optimized", [True, False])
@pytest.mark.parametrize("qname", Q.PLAN_EXECUTABLE)
def test_mock_parity_stats_identical(parity_runs, qname, optimized):
    """Padding lanes never reach OpStats: identical op/noise accounting."""
    _, base_stats, _, shard_stats, _ = parity_runs[(qname, optimized)]
    assert _stats_dict(base_stats) == _stats_dict(shard_stats)


def test_mock_parity_four_shards(db_mb):
    """3 lineitem blocks pad to 4 at shards=4 (3 zero lanes)."""
    base, base_stats, _ = _run_plan(db_mb, "Q6", True, None)
    shard, shard_stats, ledger = _run_plan(db_mb, "Q6", True, 4)
    _same(base, shard)
    assert _stats_dict(base_stats) == _stats_dict(shard_stats)
    assert ledger["shards"] == 4 and ledger["folds"] > 0


def test_ledger_models_speedup(db_mb):
    """The same query priced at 1 vs 4 shards: distributed scan time
    divides, so modeled seconds strictly drop."""
    secs = {}
    for s in (1, 4):
        plan = Q.QUERIES["Q6"][0]()
        pl = Planner(db_mb, shards=s)
        run_via_plan(pl, plan)
        assert pl.shard_ctx.dist, "scan ops should be distributed"
        secs[s] = pl.shard_ctx.modeled_seconds(COSTS)
    assert secs[4] < secs[1]


# ---------------------------------------------------------------------------
# 2. BFV micro parity: real ciphertexts, custom small-domain plans.
# ---------------------------------------------------------------------------

def _bfv_db(bk):
    """3-block fact table (300 rows at n=128) + a 4-row parent, all
    values inside [0, t/2) for t=257."""
    rng = np.random.default_rng(5)
    n = 300
    fact = TableSchema("fact", [
        ColumnSpec("g", "int"), ColumnSpec("m", "int"),
        ColumnSpec("v", "int"), ColumnSpec("pk_ref", "int"),
    ])
    parent = TableSchema("parent", [
        ColumnSpec("pid", "int"), ColumnSpec("region", "int"),
    ])
    data = {
        "g": rng.integers(1, 4, n), "m": rng.integers(1, 3, n),
        "v": rng.integers(0, 50, n), "pk_ref": rng.integers(1, 5, n),
    }
    pdata = {"pid": np.arange(1, 5), "region": np.array([1, 2, 1, 2])}
    db = Database(bk)
    db.load_table(fact, data, n)
    db.load_table(parent, pdata, 4)
    return db, data, pdata


def _bfv_plans():
    grouped = QueryPlan(
        "g1", "fact",
        where=And((Pred("g", "in", (1, 2)), Pred("m", "=", 1))),
        group_by="g", group_domain=2,
        aggs=(Agg("sum", (Factor("v"),), "sv"), Agg("count", (), "ct")))
    hop = JoinHop(parent="parent", child="fact", fk="pk_ref")
    joined = QueryPlan(
        "j1", "fact",
        where=And((Translated(hop, Pred("region", "=", 1)),
                   Pred("m", "=", 2))),
        aggs=(Agg("sum", (Factor("v"),), "sv"),))
    filtered = QueryPlan(
        "f1", "fact", where=Pred("v", "<", 20),
        aggs=(Agg("sum", (Factor("v"),), "sv"), Agg("count", (), "ct")))
    return [grouped, joined, filtered]


def _bfv_oracle(plan, data, pdata):
    t = 257
    if plan.name == "g1":
        keep = data["m"] == 1
        return {v: {"sv": int(data["v"][keep & (data["g"] == v)].sum() % t),
                    "ct": int((keep & (data["g"] == v)).sum() % t)}
                for v in (1, 2)}
    if plan.name == "j1":
        pr = dict(zip(pdata["pid"], pdata["region"]))
        keep = np.array([pr[k] == 1 for k in data["pk_ref"]]) & (data["m"] == 2)
        return {"sv": int(data["v"][keep].sum() % t)}
    keep = data["v"] < 20
    return {"sv": int(data["v"][keep].sum() % t),
            "ct": int(keep.sum() % t)}


@pytest.mark.parametrize("pname", ["g1", "j1", "f1"])
def test_bfv_micro_sharded_parity(bfv_micro, pname):
    bk = bfv_micro
    db, data, pdata = _bfv_db(bk)
    plan = next(p for p in _bfv_plans() if p.name == pname)
    bk.stats.reset()
    base = run_via_plan(Planner(db), plan)
    base_stats = bk.stats.clone()
    bk.stats.reset()
    shard = run_via_plan(Planner(db, shards=2), plan)
    shard_stats = bk.stats.clone()
    _same(base, shard)
    _same(shard, _bfv_oracle(plan, data, pdata))
    assert _stats_dict(base_stats) == _stats_dict(shard_stats)


# ---------------------------------------------------------------------------
# 3. Padding invariants.
# ---------------------------------------------------------------------------

def test_pad_to():
    assert pad_to(3, 2) == 4
    assert pad_to(3, 4) == 4
    assert pad_to(8, 4) == 8
    assert pad_to(5, 8) == 8
    assert pad_to(3, 1) == 3      # shards=1: no padding
    assert pad_to(1, 8) == 1      # singletons never pad


def test_stack_pads_only_under_context(mock_mb):
    bk = mock_mb
    blocks = [bk.encrypt(np.full(bk.slots, i + 1)) for i in range(3)]
    plain = bk.stack_blocks(blocks)
    assert bk._nblocks_phys(plain) == 3 and bk._nblocks(plain) == 3
    with activate(bk, make_shard_context(2, mesh=None)):
        padded = bk.stack_blocks(blocks)
        assert bk._nblocks_phys(padded) == 4       # 3 -> 4 lanes
        assert bk._nblocks(padded) == 3            # live count unchanged
        # pads are additive identities: fold == unpadded fold
        f_pad = bk.fold_blocks(padded)
    f_plain = bk.fold_blocks(plain)
    np.testing.assert_array_equal(bk.decrypt(f_pad), bk.decrypt(f_plain))
    # unstack returns exactly the live blocks
    outs = bk.unstack_blocks(padded)
    assert len(outs) == 3
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(bk.decrypt(o), bk.decrypt(blocks[i]))


def test_shard_context_validates():
    with pytest.raises(ValueError):
        ShardContext(0)


# ---------------------------------------------------------------------------
# 4 + 5. Per-lane noise vectors: partial refresh / ensure_levels.
# ---------------------------------------------------------------------------

def _burned_pair(bk):
    """(fresh, nearly-exhausted) same-plaintext pair."""
    fresh = bk.encrypt(np.full(bk.slots, 2))
    hot = bk.encrypt(np.full(bk.slots, 3))
    while bk.levels_left(hot) > 0:
        hot = bk.mul(hot, bk.encrypt(np.ones(bk.slots)))
    return fresh, hot


def test_partial_refresh_charges_exhausted_lane_only(mock_mb):
    bk = mock_mb
    fresh, hot = _burned_pair(bk)
    batch = bk.stack_blocks([fresh, hot])
    assert np.ndim(batch.noise) == 1          # heterogeneous -> vector
    bk.stats.reset()
    out = bk.mul(batch, batch)                # lane 1 must refresh first
    assert bk.stats.refresh == 1              # NOT 2: lane 0 still has room
    vals = [bk.decrypt(b) for b in bk.unstack_blocks(out)]
    np.testing.assert_array_equal(vals[0], np.full(bk.slots, 4))
    np.testing.assert_array_equal(vals[1], np.full(bk.slots, 9))
    bk.stats.reset()


def test_ensure_levels_refreshes_short_lanes_only(mock_mb):
    bk = mock_mb
    fresh, hot = _burned_pair(bk)
    batch = bk.stack_blocks([fresh, hot])
    per0 = np.asarray(batch.noise).copy()
    bk.stats.reset()
    bk.ensure_levels(batch, 3)
    assert bk.stats.refresh == 1
    # hot lane now fresh again; lane 0 was already fresh, so the packed
    # noise collapses back to the uniform scalar == lane 0's old value
    assert float(np.max(batch.noise)) == per0[0]
    assert bk.levels_left(batch) >= 3
    bk.stats.reset()


def test_pack_noises_scalar_when_uniform(mock_mb):
    bk = mock_mb
    blocks = [bk.encrypt(np.zeros(bk.slots)) for _ in range(3)]
    batch = bk.stack_blocks(blocks)
    assert np.ndim(batch.noise) == 0          # uniform stays scalar


# ---------------------------------------------------------------------------
# 6. Bounded WorkloadCache: LRU eviction + counters.
# ---------------------------------------------------------------------------

def _atom(i):
    return types.SimpleNamespace(key=("tbl", "c", i), table="tbl")


def test_lru_eviction_bound_and_counter(mock_mb):
    bk = mock_mb
    cache = WorkloadCache(max_entries=2)
    blocks = [bk.encrypt(np.zeros(bk.slots))]
    for i in range(4):
        cache.insert(bk, _atom(i), blocks)
    assert len(cache.entries) == 2
    assert cache.stats.evictions == 2
    assert not cache.contains(_atom(0).key) and not cache.contains(_atom(1).key)
    assert cache.contains(_atom(2).key) and cache.contains(_atom(3).key)


def test_lru_serve_refreshes_recency(mock_mb):
    bk = mock_mb
    cache = WorkloadCache(max_entries=2)
    blocks = [bk.encrypt(np.zeros(bk.slots))]
    cache.insert(bk, _atom(0), blocks)
    cache.insert(bk, _atom(1), blocks)
    assert cache.serve(bk, _atom(0), 1) is not None   # 0 becomes MRU
    cache.insert(bk, _atom(2), blocks)                # evicts 1, not 0
    assert cache.contains(_atom(0).key)
    assert not cache.contains(_atom(1).key)
    assert cache.stats.evictions == 1


def test_lru_fk_banks_bounded(mock_mb):
    bk = mock_mb
    cache = WorkloadCache(max_entries=1)
    bank = [[bk.encrypt(np.zeros(bk.slots))]]
    cache.fk_store(bk, "t", "fk_a", 4, bank)
    cache.fk_store(bk, "t", "fk_b", 4, bank)
    assert len(cache.fk_banks) == 1
    assert cache.stats.evictions == 1
    assert cache.fk_lookup(bk, "t", "fk_b", 4) is not None
    assert cache.fk_lookup(bk, "t", "fk_a", 4) is None


def test_unbounded_cache_never_evicts(mock_mb):
    bk = mock_mb
    cache = WorkloadCache()
    blocks = [bk.encrypt(np.zeros(bk.slots))]
    for i in range(8):
        cache.insert(bk, _atom(i), blocks)
    assert len(cache.entries) == 8 and cache.stats.evictions == 0


# ---------------------------------------------------------------------------
# 7. Fused broadcast_slots: one stacked launch, identical accounting.
# ---------------------------------------------------------------------------

def test_broadcast_slots_fused_parity(mock_mb):
    bk = mock_mb
    packed = bk.encrypt(np.arange(1, bk.slots + 1))
    idxs = [0, 3, 7, 11]
    bk.stats.reset()
    loop = [bk.broadcast_slot(packed, i) for i in idxs]
    loop_stats = bk.stats.clone()
    bk.stats.reset()
    fused = ops.broadcast_slots(bk, packed, idxs)
    fused_stats = bk.stats.clone()
    for l, f in zip(loop, fused):
        np.testing.assert_array_equal(bk.decrypt(l), bk.decrypt(f))
    # identical op-unit/noise accounting, strictly fewer launches
    for field in ("mul_plain", "rotate", "add", "refresh"):
        assert getattr(fused_stats, field) == getattr(loop_stats, field), field
    assert fused_stats.launches < loop_stats.launches
    bk.stats.reset()


def test_broadcast_slots_single_index_falls_back(mock_mb):
    bk = mock_mb
    packed = bk.encrypt(np.arange(bk.slots))
    [one] = ops.broadcast_slots(bk, packed, [5])
    np.testing.assert_array_equal(bk.decrypt(one), np.full(bk.slots, 5))


# ---------------------------------------------------------------------------
# 8. Elastic re-shard after straggler exclusion.
# ---------------------------------------------------------------------------

def test_elastic_scan_plan_powers_of_two():
    plan = elastic_scan_plan(8, [3])
    assert plan["shards"] == 4 and plan["workers_idle"] == 3
    assert 3 not in plan["workers"]
    plan = elastic_scan_plan(4, [])
    assert plan["shards"] == 4 and plan["workers"] == [0, 1, 2, 3]
    with pytest.raises(RuntimeError):
        elastic_scan_plan(2, [0, 1])


def test_straggler_exclusion_to_resharded_parity(db_mb):
    """Detector flags a slow worker -> elastic plan -> rerun at the
    survivor count with identical decrypted output."""
    det = StragglerDetector(threshold=2.0, patience=1)
    for step in range(3):
        for w in range(4):
            det.report(w, 10.0 if w == 3 else 1.0, now=float(step))
    excluded = det.evaluate(now=3.0)
    assert excluded == [3]
    plan = Q.QUERIES["Q6"][0]()
    pl = Planner(db_mb, shards=4)
    before = run_via_plan(pl, plan)
    pl.shard_ctx = pl.shard_ctx.reshard(excluded)
    assert pl.shard_ctx.shards == 2            # largest pow2 of 3 survivors
    after = run_via_plan(pl, plan)
    _same(before, after)


# ---------------------------------------------------------------------------
# 9. Real multi-device collectives (CI: forced 8 host devices).
# ---------------------------------------------------------------------------

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >= 2 devices (XLA_FLAGS)")


@multidevice
def test_sharded_fold_psum_matches_numpy():
    rng = np.random.default_rng(0)
    data = rng.integers(0, 1 << 30, (4, 2, 3, 16), dtype=np.int64)
    out = sharded_fold(jax.numpy.asarray(data), live=3, mesh=make_scan_mesh(2))
    np.testing.assert_array_equal(np.asarray(out), data[:3].sum(axis=0))
    # pads excluded: live=4 differs
    out4 = sharded_fold(jax.numpy.asarray(data), live=4, mesh=make_scan_mesh(2))
    assert not np.array_equal(np.asarray(out4), data[:3].sum(axis=0))


@multidevice
def test_bfv_fold_on_real_mesh_parity(bfv_micro):
    bk = bfv_micro
    vecs = [np.arange(bk.slots) % 7 + i for i in range(3)]
    blocks = [bk.encrypt(v) for v in vecs]
    base = bk.decrypt(bk.fold_blocks(bk.stack_blocks(blocks)))
    ctx = make_shard_context(2)
    assert ctx.mesh is not None
    with activate(bk, ctx):
        batch = bk.stack_blocks([bk.encrypt(v) for v in vecs])
        assert batch.nphys == 4 and batch.nblocks == 3
        got = bk.decrypt(bk.fold_blocks(batch))
    np.testing.assert_array_equal(got, base)
    np.testing.assert_array_equal(got, np.sum(vecs, axis=0) % bk.t)


@multidevice
def test_mock_query_with_real_mesh(db_mb):
    """The full plan path with a real mesh attached (mock data is numpy,
    so only the context/ledger layer sees the mesh)."""
    base, base_stats, _ = _run_plan(db_mb, "Q1", True, None)
    shard, shard_stats, ledger = _run_plan(db_mb, "Q1", True, 2)
    _same(base, shard)
    assert _stats_dict(base_stats) == _stats_dict(shard_stats)


# ---------------------------------------------------------------------------
# 10. limbops.force_ref: kernel dispatch pinned to ref inside shard_map.
# ---------------------------------------------------------------------------

def test_force_ref_overrides_kernel_dispatch(micro_params):
    from repro.core import limbops
    lo = limbops.LimbOps(micro_params.Q)
    ref = limbops.LimbOps(micro_params.Q, backend="ref")
    rng = np.random.default_rng(1)
    x = rng.integers(0, np.asarray(micro_params.Q.q).min(),
                     (lo.k, lo.n), dtype=np.int64)
    outside = lo._use_ref()
    with limbops.force_ref():
        assert lo._use_ref()
        with limbops.force_ref():              # reentrant
            assert lo._use_ref()
            np.testing.assert_array_equal(
                np.asarray(lo.ntt(x)), np.asarray(ref.ntt(x)))
        assert lo._use_ref()
    assert lo._use_ref() == outside            # counter fully unwinds
    assert lo.backend in ("ref", "pallas")     # attr itself untouched
