"""Compiled-DAG execution (engine/physical.py + engine/executor.py).

Parity: `run_via_plan(planner, plan_qN())` must decrypt to exactly the
same result as the legacy hand-written `run_qN` body AND the plaintext
oracle, in both planner regimes, on the mock backend at paper parameters
and on real RNS-BFV ciphertexts (micro domain).  The scheduler claims —
fewer fused launches at equal op-depth accounting, CSE reuse, predicted
depth/refresh counts matching the executed op history — are asserted
against OpStats.

Every ported query runs once per regime in the module-scoped `runs`
fixture (queries at the paper profile are expensive); the tests assert
on the captured results/reports.
"""
import numpy as np
import pytest

from repro.engine import queries as Q
from repro.engine.executor import Executor, run_via_plan
from repro.engine.plan import Agg, And, Factor, JoinHop, Pred, QueryPlan, Translated
from repro.engine.planner import Planner

PORTED = list(Q.PLAN_EXECUTABLE)          # Q1, Q6, Q12, Q19


def _legacy_unfused(db):
    """The pre-DAG schedule: one circuit launch per predicate, no CSE."""
    pl = Planner(db, optimized=True)
    pl.fuse_masks = False
    pl.share_masks = False
    return pl


@pytest.fixture(scope="module")
def runs(tiny_db, mock_paper):
    """One legacy + one compiled-DAG execution per (query, regime)."""
    bk = mock_paper
    out = {}
    for qn in PORTED:
        plan_f, run_f, oracle_f = Q.QUERIES[qn]
        for opt in (True, False):
            bk.stats.reset()
            bk.op_log.clear()
            legacy = run_f(Planner(tiny_db, optimized=opt))
            leg_stats = bk.stats.clone()
            bk.stats.reset()
            bk.op_log.clear()
            ex = Executor(Planner(tiny_db, optimized=opt))
            got = ex.run(plan_f(), validate=True)
            out[(qn, opt)] = {
                "legacy": legacy, "got": got, "oracle": oracle_f(tiny_db),
                "legacy_stats": leg_stats, "stats": bk.stats.clone(),
                "eq_circuits": bk.op_log["eq"], "report": ex.report,
            }
    bk.stats.reset()
    bk.op_log.clear()
    return out


@pytest.fixture(scope="module")
def unfused_runs(tiny_db, mock_paper):
    """Q1/Q19 through the legacy bodies with fusion + CSE disabled —
    the pre-DAG launch schedule the benchmark compares against."""
    bk = mock_paper
    out = {}
    for qn in ("Q1", "Q19"):
        bk.stats.reset()
        bk.op_log.clear()
        Q.QUERIES[qn][1](_legacy_unfused(tiny_db))
        out[qn] = {"stats": bk.stats.clone(), "eq_circuits": bk.op_log["eq"]}
    bk.stats.reset()
    bk.op_log.clear()
    return out


# ---------------------------------------------------------------------------
# Parity: compiled DAG == legacy body == plaintext oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("optimized", [True, False])
@pytest.mark.parametrize("qn", PORTED)
def test_via_plan_matches_legacy_and_oracle(runs, qn, optimized):
    r = runs[(qn, optimized)]
    assert r["got"] == r["legacy"], f"{qn}: DAG != legacy body"
    assert r["got"] == r["oracle"], f"{qn}: DAG != plaintext oracle"


# ---------------------------------------------------------------------------
# Scheduler: fused cross-mask launches + CSE beat the pre-DAG schedule
# at identical op-depth accounting.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qn", ["Q1", "Q19"])
def test_fused_fewer_launches_equal_depth(runs, unfused_runs, qn):
    sep = unfused_runs[qn]["stats"]
    fused = runs[(qn, True)]["stats"]
    assert fused.launches < sep.launches, (fused.launches, sep.launches)
    assert fused.mul <= sep.mul                  # CSE never adds multiplies
    assert fused.max_depth == sep.max_depth      # equal op-depth accounting
    assert fused.refresh <= sep.refresh


def test_q1_group_cse_drops_duplicate_eq_circuits(runs, unfused_runs):
    """Legacy Q1 re-evaluates the l_linestatus EQ mask for every
    l_returnflag group; the DAG evaluates each distinct (col, =, value)
    subgraph once: 5 EQ circuits instead of 9."""
    assert unfused_runs["Q1"]["eq_circuits"] == 9
    assert runs[("Q1", True)]["eq_circuits"] == 5


def test_cse_cache_reused_across_runs(tiny_db, mock_paper):
    """Second execution of the same plan on one planner re-evaluates no
    comparison circuit at all (the whole atom set hits the CSE cache)."""
    pl = Planner(tiny_db, optimized=True)
    first = run_via_plan(pl, Q.plan_q6())
    ex = Executor(pl)
    assert ex.run(Q.plan_q6()) == first
    atoms_stage = ex.report.history[0]
    assert atoms_stage["stage"] == "atoms[fused]"
    assert atoms_stage["mul"] == 0, "cached atoms must not re-run circuits"


@pytest.mark.slow
def test_group_mask_memoization_feeds_sort(tiny_db, mock_paper):
    """ORDER BY reuses the GROUP BY EQ masks through the planner cache:
    the sort pass after group_masks adds zero equality circuits."""
    bk = mock_paper
    pl = Planner(tiny_db, optimized=True)
    li = tiny_db.tables["lineitem"]
    plain = tiny_db.plain["lineitem"]["l_quantity"]
    domain = sorted(set(plain.tolist()))
    pl.group_masks(li, "l_quantity", domain)
    bk.op_log.clear()
    out = pl.sort_column(li, "l_quantity", domain)
    assert bk.op_log["eq"] == 0, "sort must reuse memoized EQ masks"
    dec = bk.decrypt(out)
    np.testing.assert_array_equal(dec[: li.nrows], np.sort(plain))


# ---------------------------------------------------------------------------
# Predicted depth / refreshes vs the executed op history.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("optimized", [True, False])
@pytest.mark.parametrize("qn", PORTED)
def test_report_matches_plan_model(runs, qn, optimized):
    r = runs[(qn, optimized)]["report"]
    r.validate()                          # the executor's own contract
    assert r.history, "executor must record an op history"
    assert r.measured_depth == max(h["max_depth"] for h in r.history)
    assert r.refreshes == sum(h["refresh"] for h in r.history)
    # Table-3 composition bounds the executed chain from above...
    assert r.measured_depth <= r.predicted_depth + 3
    if optimized:
        # ...and tightly from below in the optimized regime.
        assert r.predicted_depth <= r.measured_depth + 7
        if r.predicted_refreshes == 0:
            assert r.refreshes == 0
    if r.refreshes:
        assert r.predicted_refreshes > 0


def test_group_pushdown_keeps_extra_in_predicates(tiny_db, mock_paper):
    """Only ONE IN predicate on the group column is absorbed into the
    enumeration; further predicates on the same column stay in WHERE."""
    import numpy as np
    plan = QueryPlan(
        name="double_in", fact="lineitem",
        where=And((Pred("l_shipmode", "in", ["MAIL", "SHIP"]),
                   Pred("l_shipmode", "in", ["SHIP", "RAIL"]))),
        group_by="l_shipmode", group_domain=2,
        aggs=(Agg("count", (), "n"),))
    got = run_via_plan(Planner(tiny_db, optimized=True), plan)
    sm = tiny_db.tables["lineitem"].schema.col("l_shipmode").dictionary
    li = tiny_db.plain["lineitem"]
    both = np.isin(li["l_shipmode"], [sm["SHIP"], sm["RAIL"]])
    for mode in ("MAIL", "SHIP"):
        exp = int((both & (li["l_shipmode"] == sm[mode])).sum())
        assert got[mode]["n"] == exp, mode


def test_group_pushdown_unknown_value_is_empty_group(tiny_db, mock_paper):
    """A pushed-down group constant absent from the data behaves like
    the predicate would: an (all-zero) group, not a KeyError."""
    plan = QueryPlan(
        name="ghost_group", fact="lineitem",
        where=Pred("l_shipmode", "in", ["MAIL", "NO SUCH MODE"]),
        group_by="l_shipmode", group_domain=2,
        aggs=(Agg("count", (), "n"),))
    got = run_via_plan(Planner(tiny_db, optimized=True), plan)
    assert got["NO SUCH MODE"]["n"] == 0
    assert got["MAIL"]["n"] > 0


def test_optimized_via_plan_refresh_free(runs):
    """The headline invariant on the in-budget queries: the compiled DAG
    keeps Q1/Q6/Q12 bootstrap-free under the optimized planner."""
    for qn in ("Q1", "Q6", "Q12"):
        assert runs[(qn, True)]["report"].refreshes == 0, qn


# ---------------------------------------------------------------------------
# Real ciphertexts: the compiled DAG on the BFV backend (micro domain).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bfv_db(bfv_micro):
    from repro.engine.schema import ColumnSpec, TableSchema
    from repro.engine.storage import Database
    rng = np.random.default_rng(9)
    db = Database(bfv_micro)
    n = 40
    db.load_table(TableSchema("sales", [
        ColumnSpec("day", "int"), ColumnSpec("price", "int"),
        ColumnSpec("qty", "int"), ColumnSpec("region", "str")]), {
        "day": rng.integers(1, 101, n),
        "price": rng.integers(1, 101, n),
        "qty": rng.integers(1, 11, n),
        "region": [["N", "S", "E", "W"][i] for i in rng.integers(0, 4, n)],
    }, n)
    db.load_table(TableSchema("dim", [
        ColumnSpec("key", "int"), ColumnSpec("flag", "int")]), {
        "key": np.arange(1, 5), "flag": np.array([1, 0, 1, 0])}, 4)
    db.load_table(TableSchema("fact", [
        ColumnSpec("fk", "int"), ColumnSpec("v", "int")]), {
        "fk": rng.integers(1, 5, 24), "v": rng.integers(1, 20, 24)}, 24)
    return db


@pytest.mark.slow
def test_via_plan_group_by_on_real_he(bfv_db, bfv_micro):
    bk = bfv_micro
    t = bk.t
    plan = QueryPlan(
        name="sales_report", fact="sales",
        where=And((Pred("day", "<", 50), Pred("qty", ">=", 3))),
        group_by="region", group_domain=4,
        aggs=(Agg("sum", (Factor("price"),), "s"), Agg("count", (), "c")))
    bk.stats.reset()
    got = run_via_plan(Planner(bfv_db, optimized=True), plan)
    plain = bfv_db.plain["sales"]
    sel = (plain["day"] < 50) & (plain["qty"] >= 3)
    rdict = bfv_db.tables["sales"].schema.col("region").dictionary
    for name, rid in sorted(rdict.items()):
        m = sel & (plain["region"] == rid)
        assert got[name] == {"s": int(plain["price"][m].sum()) % t,
                             "c": int(m.sum()) % t}, name
    assert bk.stats.refresh == 0, "optimized DAG must stay in budget"


@pytest.mark.slow
def test_via_plan_translated_join_on_real_he(bfv_db, bfv_micro):
    bk = bfv_micro
    t = bk.t
    hop = JoinHop("dim", "fk", "fact")
    plan = QueryPlan(
        name="flagged_volume", fact="fact",
        where=And((Translated(hop, Pred("flag", "=", 1)), Pred("v", "<", 15))),
        aggs=(Agg("sum", (Factor("v"),), "vol"), Agg("count", (), "n")))
    got = run_via_plan(Planner(bfv_db, optimized=True), plan)
    dim, fact = bfv_db.plain["dim"], bfv_db.plain["fact"]
    m = (dim["flag"][fact["fk"] - 1] == 1) & (fact["v"] < 15)
    assert got == {"vol": int(fact["v"][m].sum()) % t, "n": int(m.sum()) % t}
