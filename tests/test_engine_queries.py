"""All nine TPC-H queries vs the plaintext oracle (mock backend at the
paper's parameter profile), optimized mode for all + unoptimized for the
three paper-anchored queries; plus planner-regime invariants."""
import pytest

from repro.engine import queries as Q
from repro.engine.planner import Planner

ALL = ["Q1", "Q4", "Q5", "Q6", "Q8", "Q12", "Q14", "Q17", "Q19"]


@pytest.fixture(scope="module")
def planner(tiny_db):
    return Planner(tiny_db, optimized=True)


@pytest.fixture(scope="module")
def planner_unopt(tiny_db):
    return Planner(tiny_db, optimized=False)


@pytest.mark.parametrize("qn", ALL)
def test_query_matches_oracle_optimized(planner, tiny_db, qn):
    _, run_f, oracle_f = Q.QUERIES[qn]
    assert run_f(planner) == oracle_f(tiny_db)


@pytest.mark.parametrize("qn", ["Q6", "Q14", "Q8"])
def test_query_matches_oracle_unoptimized(planner_unopt, tiny_db, qn):
    _, run_f, oracle_f = Q.QUERIES[qn]
    assert run_f(planner_unopt) == oracle_f(tiny_db)


def test_optimizer_reduces_refreshes(tiny_db, mock_paper):
    """The paper's headline: noise-aware planning eliminates/reduces
    bootstrap-equivalents on join-heavy queries."""
    bk = mock_paper
    results = {}
    for optimized in (True, False):
        pl = Planner(tiny_db, optimized=optimized)
        bk.stats.reset()
        Q.run_q14(pl)
        results[optimized] = bk.stats.refresh
    assert results[True] < results[False]
    assert results[True] == 0


def test_storage_expansion_matches_paper(mock_paper):
    """§4.1: '0.27 MB of raw data expands to a 7.4 MB ciphertext' (~28x) —
    the paper's raw baseline is 64-bit words (0.27MB / 32768 = 8 B)."""
    prof = mock_paper.profile
    assert 7.0e6 < prof.ct_bytes < 8.5e6, prof.ct_bytes     # ~7.4 MB
    ratio = prof.expansion_ratio(raw_bits=64)
    assert 25 < ratio < 35, ratio


def test_exact_partial_sums(tiny_db, mock_paper):
    """Beyond-paper exact aggregation: chunked partial sums reconstruct
    the exact (un-wrapped) SUM client-side."""
    import numpy as np
    from repro.engine import ops
    bk = mock_paper
    li = tiny_db.tables["lineitem"]
    mask = [bk.encrypt(np.ones(li.nrows, dtype=np.int64))]
    mask = ops.apply_validity(bk, mask, li)
    chunk = 8
    outs = ops.partial_sums(bk, li.col("l_quantity").blocks, mask, chunk)
    dec = bk.decrypt(outs[0])
    half = bk.slots // 2
    exact = 0
    for row in (dec[:half], dec[half:]):
        exact += int(row[::chunk].sum())
    assert exact == int(tiny_db.plain["lineitem"]["l_quantity"].sum())


@pytest.mark.slow
def test_order_by_sorted_reconstruction(tiny_db, mock_paper):
    """§4.2.3 ORDER BY: the engine reconstructs an encrypted *sorted*
    sequence by domain enumeration + prefix placement."""
    import numpy as np
    from repro.engine import ops
    bk = mock_paper
    li = tiny_db.tables["lineitem"]
    plain = tiny_db.plain["lineitem"]["l_quantity"]
    domain = sorted(set(plain.tolist()))
    out = ops.sort_column(bk, li, "l_quantity", domain)
    dec = bk.decrypt(out)
    got = dec[: li.nrows]
    # slot layout is 2 rows x n/2: rows fit in row 0 at tiny scale
    np.testing.assert_array_equal(got, np.sort(plain))
    # slots past nrows hold zeros (nothing placed)
    assert int(dec[li.nrows]) == 0
