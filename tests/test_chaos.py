"""Chaos suite: deterministic fault injection against the execution
runtime (runtime/faults.py, DESIGN.md §9).

The acceptance contract under test: for every injected fault class —
noise under-prediction, device loss mid-scan, straggler exclusion,
cache corruption, checkpoint truncation — a query over the Q1/Q6/Q12/
Q19 mix either decrypts byte-identical to the fault-free run or raises
a typed ExecutionFault.  Zero silent wrong answers.

All scenarios are seeded and counter-driven (FaultPlan fires on fixed
call counts, never randomness or wall-clock), so the matrix is
reproducible run to run; CI's tests-chaos lane executes it under 8
forced host devices.  The profile is the multi-block paper-noise set
(n=64, t=65537, k=30): tiny-scale lineitem packs to 3 blocks, so the
sharded fold, padding and per-stage checkpoints are all genuinely
exercised.
"""
import os

import numpy as np
import pytest

from repro.core.noise import NoiseProfile, UnderReportingNoiseModel
from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.executor import (MAX_DEVICE_LOSS_RECOVERIES, ExecReport,
                                   run_via_plan)
from repro.engine.planner import Planner
from repro.engine.workload import WorkloadCache
from repro.runtime import faults
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import StragglerDetector

SEED = int(os.environ.get("NSHEDB_CHAOS_SEED", "1234"))
MULTIBLOCK = NoiseProfile(n=64, t=65537, k=30)
MIX = Q.PLAN_EXECUTABLE                      # Q1 Q6 Q12 Q19
COSTS = {"mul": 0.05, "mul_plain": 0.055, "mul_scalar": 0.002,
         "add": 0.0015, "rotate": 0.105, "refresh": 44.0}


@pytest.fixture(scope="module")
def mock_mb():
    return MockBackend(MULTIBLOCK)


@pytest.fixture(scope="module")
def db_mb(mock_mb):
    return tpch.load(mock_mb, tpch.Scale.tiny(), seed=7)


@pytest.fixture(scope="module")
def baselines(db_mb):
    """Fault-free reference results per query (single-device, no guards
    — the bytes every recovered run must reproduce)."""
    return {qn: run_via_plan(Planner(db_mb, optimized=True),
                             Q.QUERIES[qn][0]())
            for qn in MIX}


def _run_faulted(db, qname, plan_obj, shards=2, planner_kw=None):
    pl = Planner(db, optimized=True, shards=shards, **(planner_kw or {}))
    with faults.inject(plan_obj):
        out = run_via_plan(pl, Q.QUERIES[qname][0]())
    return out, pl


# ---------------------------------------------------------------------------
# Guards are inert on healthy runs.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", MIX)
def test_guarded_run_matches_fault_free(db_mb, baselines, qname):
    """Armed guards (headroom check + sentinel lane) must not perturb a
    healthy execution: identical decrypts, zero recovery events."""
    out, pl = _run_faulted(db_mb, qname, faults.FaultPlan())
    assert out == baselines[qname]
    pl2 = Planner(db_mb, optimized=True, guards=True)
    assert run_via_plan(pl2, Q.QUERIES[qname][0]()) == baselines[qname]


# ---------------------------------------------------------------------------
# Fault class: noise under-prediction (overflow).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", MIX)
def test_underprediction_recovers_identical(db_mb, baselines, qname):
    """A transient model mispredict (3 tampered muls hiding 500 bits
    each) trips the decrypt-boundary guard; refresh-and-retry must
    reproduce the fault-free bytes and report the recovery."""
    fp = faults.FaultPlan(underpredict_bits=500.0, underpredict_count=3)
    out, _ = _run_faulted(db_mb, qname, fp)
    assert out == baselines[qname]
    assert fp.fired("underpredict") == 3


def test_underprediction_recovery_is_reported(db_mb, baselines):
    from repro.engine.executor import Executor
    pl = Planner(db_mb, optimized=True, shards=2)
    ex = Executor(pl)
    with faults.inject(faults.FaultPlan(underpredict_bits=500.0,
                                        underpredict_count=3)):
        out = ex.run(Q.QUERIES["Q6"][0]())
    assert out == baselines["Q6"]
    kinds = [r["kind"] for r in ex.report.recoveries]
    assert "overflow" in kinds
    actions = [r["action"] for r in ex.report.recoveries]
    assert "refresh-and-retry" in actions


@pytest.mark.parametrize("qname", MIX)
def test_persistent_underprediction_raises_typed(db_mb, qname):
    """A persistent model bias can not be refreshed away: after the
    bounded retries the run must fail typed, never return garbage."""
    fp = faults.FaultPlan(underpredict_bits=500.0, underpredict_count=10**9)
    with pytest.raises(faults.NoiseOverflowFault) as ei:
        _run_faulted(db_mb, qname, fp)
    assert ei.value.kind == "overflow"
    assert isinstance(ei.value, faults.ExecutionFault)


def test_underreporting_model_tracks_hidden_bits():
    m = UnderReportingNoiseModel(MockBackend(MULTIBLOCK).model, 100.0, skip=1)
    v = m.fresh()
    a = m.mul(v, v)            # skipped: truthful
    b = m.mul(v, v)            # tampered: 100 bits hidden
    assert a == b + 100.0
    assert m.hidden_bits == 100.0
    assert m.budget(v) == m.inner.budget(v)   # delegation intact


# ---------------------------------------------------------------------------
# Fault class: device loss mid-scan.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", MIX)
@pytest.mark.parametrize("stage", ["where", "fold", "aggregate"])
def test_device_loss_resumes_identical(db_mb, baselines, qname, stage):
    """Losing a worker mid-stage (including inside the block fold) must
    reshard onto the survivors and resume from the last checkpoint,
    reproducing the fault-free bytes."""
    fp = faults.FaultPlan(device_loss_stage=stage, device_loss_worker=1)
    out, pl = _run_faulted(db_mb, qname, fp)
    assert out == baselines[qname]
    assert pl.shard_ctx.shards == 1           # 2 -> 1 after exclusion
    assert fp.fired("device-loss") == 1


def test_device_loss_resume_skips_completed_stages(db_mb, baselines):
    """Loss at the aggregate must resume *after* the mask stages — the
    checkpoint, not a from-scratch rerun."""
    from repro.engine.executor import Executor
    pl = Planner(db_mb, optimized=True, shards=2)
    ex = Executor(pl)
    with faults.inject(faults.FaultPlan(device_loss_stage="aggregate",
                                        device_loss_worker=1)):
        out = ex.run(Q.QUERIES["Q6"][0]())
    assert out == baselines["Q6"]
    (rec,) = [r for r in ex.report.recoveries if r["kind"] == "device-loss"]
    assert "atoms" in rec["action"] and "where" in rec["action"]
    # the where stage ran exactly once across both attempts
    assert sum(1 for h in ex.report.history if h["stage"] == "where") == 1


def test_repeated_device_loss_exhausts_typed(db_mb):
    """A fault that refires on every attempt must exhaust the bounded
    recovery budget and surface typed."""
    fp = faults.FaultPlan(device_loss_stage="aggregate", device_loss_worker=0,
                          device_loss_count=10**9)
    with pytest.raises(faults.DeviceLossFault) as ei:
        _run_faulted(db_mb, "Q6", fp)
    assert ei.value.kind == "device-loss"
    # bounded: initial failure + at most MAX recoveries
    assert fp.fired("device-loss") <= MAX_DEVICE_LOSS_RECOVERIES + 1


def test_device_loss_without_shards_is_typed(db_mb):
    """No shard context -> nothing to reshard onto: the fault propagates
    typed instead of looping."""
    fp = faults.FaultPlan(device_loss_stage="aggregate", device_loss_worker=0)
    pl = Planner(db_mb, optimized=True)
    with faults.inject(fp):
        with pytest.raises(faults.DeviceLossFault):
            run_via_plan(pl, Q.QUERIES["Q6"][0]())


# ---------------------------------------------------------------------------
# Fault class: straggler exclusion.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", MIX)
def test_straggler_excluded_and_resharded(db_mb, baselines, qname):
    """A 10x-slow worker (synthetic heartbeats from the cost ledger) is
    struck out after `patience` rounds; the mesh shrinks 4->2 and
    results stay identical throughout."""
    pl = Planner(db_mb, optimized=True, shards=4)
    det = StragglerDetector(threshold=2.0, patience=2, timeout_s=1e9)
    pl.attach_straggler_detector(det, COSTS)
    with faults.inject(faults.FaultPlan(straggler_slowdown={3: 10.0})):
        for _ in range(3):
            out = run_via_plan(pl, Q.QUERIES[qname][0]())
            assert out == baselines[qname]
    assert pl.shard_ctx.shards == 2
    assert 3 in det.workers and det.workers[3].strikes >= det.patience


def test_straggler_heartbeats_come_from_ledger(db_mb):
    """Heartbeats are the run's modeled seconds, not wall-clock: equal
    for healthy workers, scaled for the slowed one."""
    pl = Planner(db_mb, optimized=True, shards=4)
    det = StragglerDetector(threshold=2.0, patience=3, timeout_s=1e9)
    pl.attach_straggler_detector(det, COSTS)
    with faults.inject(faults.FaultPlan(straggler_slowdown={2: 5.0})):
        run_via_plan(pl, Q.QUERIES["Q6"][0]())
    e0, e2 = det.workers[0].ewma, det.workers[2].ewma
    assert e0 > 0 and abs(e2 - 5.0 * e0) < 1e-9


# ---------------------------------------------------------------------------
# Fault class: cache poisoning.
# ---------------------------------------------------------------------------

def test_cache_poison_detected_and_rederived(db_mb, baselines):
    """Default integrity ('rederive'): tampered entries fail their
    fingerprint at serve, are dropped, and the circuits re-derive —
    identical results, poison_drops counted."""
    bk = db_mb.bk
    cache = WorkloadCache()
    pl = Planner(db_mb, optimized=True, cache=cache)
    assert run_via_plan(pl, Q.QUERIES["Q6"][0]()) == baselines["Q6"]
    faults.poison_cache(cache, bk, entries=None)
    assert run_via_plan(pl, Q.QUERIES["Q6"][0]()) == baselines["Q6"]
    assert cache.stats.poison_drops > 0


@pytest.mark.parametrize("qname", MIX)
def test_cache_poison_matrix(db_mb, baselines, qname):
    bk = db_mb.bk
    cache = WorkloadCache()
    pl = Planner(db_mb, optimized=True, cache=cache)
    run_via_plan(pl, Q.QUERIES[qname][0]())
    faults.poison_cache(cache, bk, entries=None)
    out = run_via_plan(pl, Q.QUERIES[qname][0]())
    assert out == baselines[qname]
    assert cache.stats.poison_drops > 0


def test_cache_poison_strict_mode_raises_typed(db_mb):
    bk = db_mb.bk
    cache = WorkloadCache(integrity="fail")
    pl = Planner(db_mb, optimized=True, cache=cache)
    run_via_plan(pl, Q.QUERIES["Q6"][0]())
    faults.poison_cache(cache, bk, entries=1)
    with pytest.raises(faults.CachePoisonFault) as ei:
        run_via_plan(pl, Q.QUERIES["Q6"][0]())
    assert ei.value.kind == "cache-poison"


def test_cache_poison_silent_without_integrity(db_mb, baselines):
    """Negative control: with integrity off the poisoned entry IS a
    silent wrong answer — proof the fingerprint check is load-bearing,
    not redundant with some other guard."""
    bk = db_mb.bk
    cache = WorkloadCache(integrity="off")
    pl = Planner(db_mb, optimized=True, cache=cache)
    run_via_plan(pl, Q.QUERIES["Q6"][0]())
    faults.poison_cache(cache, bk, entries=None)
    assert run_via_plan(pl, Q.QUERIES["Q6"][0]()) != baselines["Q6"]


def test_bfv_fingerprints_degrade_to_none():
    """Opaque handles (real BFV: refresh re-encrypts content) must
    yield fp=None entries — integrity silently off, never a spurious
    poison verdict."""
    from repro.core.params import make_params
    from repro.engine.backend import BFVBackend
    bk = BFVBackend(make_params(n=128, t=257, k=12), seed=11)
    assert bk.fingerprint(bk.encrypt(np.arange(4))) is None
    assert faults.fingerprint_blocks(bk, [bk.encrypt(np.arange(4))]) is None


# ---------------------------------------------------------------------------
# Fault class: checkpoint truncation.
# ---------------------------------------------------------------------------

class TestCheckpointCorruption:
    PARAMS = {"w": np.arange(64, dtype=np.float32),
              "b": np.ones(8, dtype=np.float64)}

    def test_truncated_leaf_falls_back(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
        mgr.save(1, self.PARAMS, extra={"cursor": 10})
        mgr.save(2, self.PARAMS, extra={"cursor": 20})
        faults.truncate_checkpoint(str(tmp_path), 2)
        assert not mgr.verify_step(2) and mgr.verify_step(1)
        step, params, _, extra = mgr.restore_latest_valid(self.PARAMS)
        assert step == 1 and extra == {"cursor": 10}
        np.testing.assert_array_equal(params["w"], self.PARAMS["w"])

    def test_all_corrupt_raises_typed(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
        mgr.save(1, self.PARAMS)
        mgr.save(2, self.PARAMS)
        faults.truncate_checkpoint(str(tmp_path), 1)
        faults.truncate_checkpoint(str(tmp_path), 2)
        with pytest.raises(faults.CheckpointCorruptFault) as ei:
            mgr.restore_latest_valid(self.PARAMS)
        assert ei.value.kind == "checkpoint-corrupt"
        assert sorted(ei.value.detail["skipped"]) == [1, 2]

    def test_direct_restore_of_corrupt_step_is_typed(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
        mgr.save(1, self.PARAMS)
        faults.truncate_checkpoint(str(tmp_path), 1)
        with pytest.raises(faults.CheckpointCorruptFault):
            mgr.restore(1, self.PARAMS)


# ---------------------------------------------------------------------------
# The seeded acceptance matrix: every fault class x the query mix.
# ---------------------------------------------------------------------------

FAULT_CLASSES = ["overflow-transient", "overflow-persistent",
                 "device-loss", "straggler", "cache-poison"]
# checkpoint-truncate is query-independent (the store holds training
# state, not per-query masks) — covered by TestCheckpointCorruption.


@pytest.mark.parametrize("qname", MIX)
@pytest.mark.parametrize("fault", FAULT_CLASSES)
def test_chaos_matrix_no_silent_wrong_answers(db_mb, baselines, fault, qname):
    """The ISSUE's acceptance criterion, verbatim: each fault class on
    each query of the mix ends in byte-identical decrypts or a typed
    ExecutionFault."""
    rng = np.random.default_rng(SEED)        # seeds future randomized faults
    bk = db_mb.bk
    try:
        if fault == "overflow-transient":
            fp = faults.FaultPlan(underpredict_bits=400.0 + 100 * rng.integers(3),
                                  underpredict_count=2)
            out, _ = _run_faulted(db_mb, qname, fp)
        elif fault == "overflow-persistent":
            fp = faults.FaultPlan(underpredict_bits=500.0,
                                  underpredict_count=10**9)
            out, _ = _run_faulted(db_mb, qname, fp)
        elif fault == "device-loss":
            fp = faults.FaultPlan(device_loss_stage="any",
                                  device_loss_worker=int(rng.integers(2)))
            out, _ = _run_faulted(db_mb, qname, fp)
        elif fault == "straggler":
            pl = Planner(db_mb, optimized=True, shards=4)
            det = StragglerDetector(threshold=2.0, patience=1, timeout_s=1e9)
            pl.attach_straggler_detector(det, COSTS)
            with faults.inject(faults.FaultPlan(straggler_slowdown={1: 8.0})):
                run_via_plan(pl, Q.QUERIES[qname][0]())
                out = run_via_plan(pl, Q.QUERIES[qname][0]())
        else:  # cache-poison
            cache = WorkloadCache()
            pl = Planner(db_mb, optimized=True, cache=cache)
            run_via_plan(pl, Q.QUERIES[qname][0]())
            faults.poison_cache(cache, bk, entries=None)
            out = run_via_plan(pl, Q.QUERIES[qname][0]())
    except faults.ExecutionFault as e:
        assert e.kind in ("overflow", "device-loss", "straggler",
                          "cache-poison"), e
        return                                # typed failure: contract held
    assert out == baselines[qname], f"{fault}/{qname}: silent wrong answer"


# ---------------------------------------------------------------------------
# Satellite regressions (here rather than test_runtime.py: that module
# skips wholesale without hypothesis, and these must run in every lane).
# ---------------------------------------------------------------------------

def test_straggler_evaluate_idempotent():
    """Re-evaluating without fresh heartbeats must not accrue strikes:
    only rounds with new reports are judged (reports/judged watermark)."""
    det = StragglerDetector(threshold=2.0, patience=3, timeout_s=1e9)
    for w in range(4):
        det.report(w, 1.0 if w != 3 else 9.0, now=1.0)
    for _ in range(5):                       # one round, five evaluations
        excluded = det.evaluate(now=1.0)
    assert excluded == []
    assert det.workers[3].strikes == 1       # one strike, not five
    for t in (2.0, 3.0):                     # genuine slow rounds do exclude
        for w in range(4):
            det.report(w, 1.0 if w != 3 else 9.0, now=t)
        excluded = det.evaluate(now=t)
    assert excluded == [3]


def test_straggler_reset_readmits():
    det = StragglerDetector(threshold=2.0, patience=1, timeout_s=1e9)
    for w in range(4):
        det.report(w, 1.0 if w != 2 else 9.0, now=1.0)
    assert det.evaluate(now=1.0) == [2]
    det.reset(2)                             # e.g. replaced hardware
    assert 2 not in det.workers
    for w in range(4):
        det.report(w, 1.0, now=2.0)
    assert det.evaluate(now=2.0) == []       # back at full speed, readmitted


def test_checkpoint_crash_between_write_and_rename(tmp_path, monkeypatch):
    """Kill the process after the tmp dir is fully written but before
    the atomic rename publishes it: the step must not exist, and restore
    falls back to the previous one."""
    import os as _os
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    params = TestCheckpointCorruption.PARAMS
    mgr.save(1, params, extra={"cursor": 1})

    real_rename = _os.rename

    def crash_rename(src, dst):
        raise OSError("simulated crash before publish")

    monkeypatch.setattr(_os, "rename", crash_rename)
    with pytest.raises(OSError):
        mgr.save(2, params, extra={"cursor": 2})
    monkeypatch.setattr(_os, "rename", real_rename)

    assert mgr.all_steps() == [1]            # step 2 never published
    step, got, _, extra = mgr.restore_latest_valid(params)
    assert step == 1 and extra == {"cursor": 1}
    np.testing.assert_array_equal(got["w"], params["w"])
    # and with nothing published at all, the failure is typed
    empty = CheckpointManager(str(tmp_path / "empty"), async_write=False)
    with pytest.raises(faults.CheckpointCorruptFault):
        empty.restore_latest_valid(params)


def test_validate_failure_prints_op_history_diff():
    """A plan-model violation must carry the expected-vs-observed diff
    so chaos failures are diagnosable from the assertion message."""
    rep = ExecReport("Qx", True, predicted_depth=4, predicted_refreshes=0,
                     budget_levels=12, measured_depth=30, refreshes=2,
                     launches=7, muls=9)
    rep.history.append({"stage": "where", "mul": 9, "add": 3, "rotate": 1,
                        "launches": 7, "refresh": 2, "max_depth": 30})
    with pytest.raises(AssertionError) as ei:
        rep.validate()
    msg = str(ei.value)
    assert "op-history diff for Qx" in msg
    assert "predicted=4" in msg and "measured=30" in msg
    assert "where" in msg                    # per-stage table included


def test_recovered_report_skips_plan_model_validation():
    rep = ExecReport("Qx", True, predicted_depth=4, predicted_refreshes=0,
                     budget_levels=12, measured_depth=30, refreshes=2)
    rep.recoveries.append({"kind": "overflow", "action": "refresh-and-retry"})
    rep.validate()                           # incomparable history: no raise
    rep2 = ExecReport("Qy", True, predicted_depth=4, predicted_refreshes=0,
                      budget_levels=12, measured_depth=30, refreshes=2)
    rep2.recoveries.append({"kind": "straggler", "action": "reshard 4->2"})
    with pytest.raises(AssertionError):      # straggler does NOT exempt
        rep2.validate()
