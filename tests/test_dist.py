"""Distribution layer: sharding rules + the nshedb distributed step.

Multi-device behaviour needs its own process (jax pins the device count
at first init), so the mesh test shells out with
xla_force_host_platform_device_count=16 and lowers a sharded step on a
4x4 mesh — a miniature of what launch/dryrun.py does at 512.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp


def test_nshedb_query_step_runs_and_stays_reduced():
    """Smoke config on one device: output in range, shapes preserved."""
    from repro.configs.nshedb import smoke
    from repro.launch import nshedb_step as Q

    cfg = smoke()
    consts = Q.make_constants(cfg)
    rng = np.random.default_rng(0)
    nblocks = 4
    q = consts["q"]
    ct = rng.integers(0, q[None, None, :, None],
                      (nblocks, 2, cfg.k, cfg.n)).astype(np.uint32)
    ksk = rng.integers(0, q[None, :, None], (cfg.k, cfg.k, cfg.n)).astype(np.uint32)
    out = jax.jit(lambda *a: Q.query_step(*a, eq_levels=cfg.eq_levels,
                                          rot_steps=cfg.rot_steps))(
        jnp.asarray(ct), jnp.asarray(ct), jnp.asarray(ksk), jnp.asarray(ksk),
        jnp.asarray(ksk), jnp.asarray(ksk), jnp.asarray(consts["q"]),
        jnp.asarray(consts["mu"]), jnp.asarray(consts["perm"]))
    out = np.asarray(out)
    assert out.shape == (2, cfg.k, cfg.n)
    assert np.all(out < q[None, :, None]), "residues must stay reduced"


def test_keyswitch_digit_contraction_is_exact():
    """keyswitch() must equal the int64 reference contraction."""
    from repro.configs.nshedb import smoke
    from repro.launch import nshedb_step as Q

    cfg = smoke()
    consts = Q.make_constants(cfg)
    rng = np.random.default_rng(1)
    q = consts["q"].astype(np.int64)
    poly = rng.integers(0, q[:, None], (cfg.k, cfg.n))
    kb = rng.integers(0, q[None, :, None], (cfg.k, cfg.k, cfg.n))
    ka = rng.integers(0, q[None, :, None], (cfg.k, cfg.k, cfg.n))
    got_b, got_a = Q.keyswitch(jnp.asarray(poly, jnp.uint32),
                               jnp.asarray(kb, jnp.uint32),
                               jnp.asarray(ka, jnp.uint32),
                               jnp.asarray(consts["q"]), jnp.asarray(consts["mu"]))
    exp_b = (poly[:, None, :] * kb % q[None, :, None]).sum(0) % q[:, None]
    exp_a = (poly[:, None, :] * ka % q[None, :, None]).sum(0) % q[:, None]
    assert np.array_equal(np.asarray(got_b, dtype=np.int64), exp_b)
    assert np.array_equal(np.asarray(got_a, dtype=np.int64), exp_a)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    jax.config.update("jax_enable_x64", True)

    from repro.configs import get_smoke_config
    from repro.dist.sharding import param_sharding, input_sharding
    from repro.models import lm
    from repro.train import steps as steps_mod
    from repro.train.optim import adamw_init

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_smoke_config("qwen2-72b")
    pshapes = jax.eval_shape(lambda k: lm.init_params(k, cfg, jnp.float32),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
    pshard = param_sharding(pshapes, mesh)
    # embed (vocab=128, d=64): vocab shards over model=4
    assert pshard["embed"].spec == P("model", None), pshard["embed"].spec
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    bshard = input_sharding(batch, mesh)
    oshapes = {"adam": jax.eval_shape(adamw_init, pshapes)}
    oshard = {"adam": param_sharding(oshapes["adam"], mesh)}
    step = steps_mod.make_train_step(cfg)
    with mesh:
        lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard)).lower(
            pshapes, oshapes, batch)
        compiled = lowered.compile()
    txt = compiled.as_text()
    has_coll = any(k in txt for k in ("all-reduce", "all-gather", "reduce-scatter"))
    print(json.dumps({"ok": True, "has_collectives": has_coll}))
""")


@pytest.mark.slow
def test_sharded_train_step_lowers_on_16_devices():
    pytest.importorskip("repro.dist.sharding")  # sharding module not landed yet
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["has_collectives"]
