"""Noise-aware planner: the Table-3 depth model must bound measured
depth; the i* injection rule; budget-level computation."""
import math

import numpy as np
import pytest

from repro.engine import queries as Q
from repro.engine.plan import And, Pred, eq_depth, lt_depth
from repro.engine.planner import Planner, injection_depth, noise_budget_levels


def test_budget_levels_paper_params(mock_paper):
    """logQ=881-ish, t=65537, n=32768 -> ~25 levels (paper's LHE margin:
    one EQ chain of 16 plus plan glue fits; two chained EQs do not)."""
    b = noise_budget_levels(mock_paper)
    assert 20 <= b <= 30, b
    assert b > eq_depth(mock_paper.t) + 4          # one EQ + glue fits
    assert b < 2 * eq_depth(mock_paper.t)          # two chained EQs do not


def test_injection_depth_rule():
    # D_i = (m - i) * d_s <= B
    assert injection_depth(m_stages=3, d_s=17, budget=25) == 2
    assert injection_depth(m_stages=3, d_s=17, budget=60) == 0
    assert injection_depth(m_stages=3, d_s=17, budget=5) == 3  # pay one boot


@pytest.mark.parametrize("qn", ["Q1", "Q6", "Q14", "Q12"])
def test_depth_model_bounds_measurement(tiny_db, mock_paper, qn):
    """Predicted depth (Table 3 composition) must be >= the measured max
    multiplicative depth and within a small constant of it."""
    plan_f, run_f, _ = Q.QUERIES[qn]
    pl = Planner(tiny_db, optimized=True)
    mock_paper.stats.reset()
    run_f(pl)
    measured = mock_paper.stats.max_depth
    predicted = plan_f().total_depth(mock_paper.t, optimized=True)
    assert measured <= predicted + 3, (measured, predicted)
    assert predicted <= measured + 6, (measured, predicted)


def test_optimized_depth_never_higher(tiny_db):
    t = tiny_db.bk.t
    for qn, (plan_f, _, _) in Q.QUERIES.items():
        p = plan_f()
        assert p.total_depth(t, True) <= p.total_depth(t, False), qn


def test_fig3_q4_depth_reduction():
    """Fig. 3: pull-up + late injection saves ~2 EQ depths on Q4-like
    JOIN-WHERE pipelines."""
    t = 65537
    plan = Q.plan_q4()
    d_opt = plan.total_depth(t, optimized=True)
    d_orig = plan.total_depth(t, optimized=False)
    assert d_orig - d_opt >= eq_depth(t) // 2


def test_predicate_depths():
    t = 65537
    assert Pred("c", "=", 1).depth(t) == 16
    assert Pred("c", "<", 1).depth(t) == 17
    assert Pred("c", "between", (1, 2)).depth(t) == 18
    a = And((Pred("c", "=", 1), Pred("d", "=", 2), Pred("e", "=", 3),
             Pred("f", "=", 4)))
    assert a.depth(t, True) == 16 + 2      # balanced tree
    assert a.depth(t, False) == 16 + 3     # chain
