"""WorkloadCache + run_workload (engine/workload.py): noise-aware cache
admission, cross-query fused scheduling, invalidation, fk-bank reuse.

The regression anchor is the noise-unaware CSE bug: one planner's cache
serving mask blocks across plans with different `downstream_muls`.  A
deep plan's planned refresh mutates cached blocks in place; a shallow
plan then consumed them at the wrong noise point and tripped
`ExecReport.validate` (prediction overshoot / unpredicted refreshes).
With WorkloadCache admission both plans must validate in both regimes.

Fast unit tests run on a micro mock profile; the Q1→Q6→Q12→Q19 workload
mix runs once at the paper profile in a module-scoped fixture.
"""
import numpy as np
import pytest

from repro.core.noise import NoiseProfile
from repro.engine import queries as Q
from repro.engine.backend import MockBackend
from repro.engine.executor import Executor, run_via_plan
from repro.engine.physical import CmpAtom
from repro.engine.plan import (Agg, And, Factor, JoinHop, Pred, QueryPlan,
                               Translated)
from repro.engine.planner import Planner, noise_budget_levels
from repro.engine.schema import ColumnSpec, TableSchema
from repro.engine.storage import Database
from repro.engine.workload import WorkloadCache, run_workload

MIX = list(Q.PLAN_EXECUTABLE)             # Q1, Q6, Q12, Q19


# ---------------------------------------------------------------------------
# Micro-profile helpers (t=257 comparison circuits: milliseconds/test).
# ---------------------------------------------------------------------------

def _micro_db(seed=3, nrows=60):
    bk = MockBackend(NoiseProfile(n=128, t=257, k=30))
    db = Database(bk)
    rng = np.random.default_rng(seed)
    db.load_table(TableSchema("t", [
        ColumnSpec("a", "int"), ColumnSpec("b", "int"),
        ColumnSpec("v", "int")]), {
        "a": rng.integers(1, 50, nrows), "b": rng.integers(1, 50, nrows),
        "v": rng.integers(1, 20, nrows)}, nrows)
    return bk, db


def _degrade(bk, blocks, keep_levels=0):
    """Consume a cached entry's noise budget in place (what a chain of
    ct-ct products on an aliased handle does), down to `keep_levels`."""
    for b in blocks:
        while bk.levels_left(b) > keep_levels:
            b.noise = bk.model.keyswitch(bk.model.mul(b.noise, b.noise))
            b.depth += 1


def _plan(name, where, fact="t"):
    return QueryPlan(name=name, fact=fact, where=where,
                     aggs=(Agg("sum", (Factor("v"),), "s"),
                           Agg("count", (), "n")))


# ---------------------------------------------------------------------------
# Regression: plans with different downstream_muls on ONE shared cache.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("optimized", [True, False])
def test_shared_cache_two_depth_regimes_validate(tiny_db, mock_paper,
                                                 optimized):
    """The ISSUE's bug reproducer: a deep plan (translated LT mask whose
    planned refresh rejuvenates the cached blocks in place) followed by a
    shallow plan consuming the same atom.  Pre-fix the shallow run
    tripped validate() with a prediction overshoot; the noise-aware
    cache + hit-aware report must pass in both regimes."""
    pl = Planner(tiny_db, optimized=optimized)
    P = Pred("p_size", "<", 26)
    deep = QueryPlan(
        name="deepA", fact="lineitem",
        where=And((Translated(JoinHop("part", "l_partkey", "lineitem"), P),
                   Pred("l_quantity", ">=", 1), Pred("l_quantity", "<=", 50),
                   Pred("l_discount", ">=", 0),
                   Pred("l_shipdate", "<", 19980101))),
        group_by="l_returnflag",
        aggs=(Agg("sum", (Factor("l_extendedprice"), Factor("l_discount"),
                          Factor("l_quantity")), "x"),))
    shallow = QueryPlan(name="shallowB", fact="part", where=P,
                        aggs=(Agg("count", (), "n"),))

    exA = Executor(pl)
    gotA = exA.run(deep, validate=True)          # raises pre-fix semantics
    rA = exA.report
    if rA.refreshes - rA.cache_admit_refreshes > 0:
        assert rA.predicted_refreshes > 0       # refreshes stay predicted

    exB = Executor(pl)
    gotB = exB.run(shallow, validate=True)      # tripped before the fix
    if optimized:
        assert exB.report.cache_hits > 0, "shallowB must consume the cache"

    # Parity: shared-cache answers == cold fresh-planner answers.
    cold = Planner(tiny_db, optimized=optimized)
    assert gotA == run_via_plan(cold, deep, validate=False)
    assert gotB == run_via_plan(Planner(tiny_db, optimized=optimized),
                                shallow, validate=False)


# ---------------------------------------------------------------------------
# Admission unit tests (micro profile).
# ---------------------------------------------------------------------------

def test_admission_refreshes_degraded_entry():
    """An entry whose blocks degraded below the consumer's need is
    refreshed at admission: charged to OpStats, counted in the cache
    stats, levels restored to min(need, budget)."""
    bk, db = _micro_db()
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache)
    ex = Executor(pl)
    ex.run(_plan("warmup", Pred("a", "=", 7)), validate=True)
    atom = CmpAtom("t", "a", "eq", 7)
    entry = cache.entries[atom.key]
    _degrade(bk, entry.blocks, keep_levels=1)
    refr0 = bk.stats.refresh
    need = entry.born_levels                    # deeper than what's left
    served = cache.serve(bk, atom, need)
    assert served is entry.blocks
    assert cache.stats.admit_refreshes == 1
    assert cache.stats.admit_refresh_blocks == len(entry.blocks)
    assert bk.stats.refresh - refr0 == len(entry.blocks)
    want = min(need, noise_budget_levels(bk))
    assert all(bk.levels_left(b) >= want for b in entry.blocks)


def test_admission_serves_when_entry_matches_cold_derivation():
    """An entry at its born levels is served as-is even for a consumer
    whose need exceeds them — a fresh derivation could do no better, so
    cold-equivalence admits without a refresh."""
    bk, db = _micro_db()
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache)
    Executor(pl).run(_plan("warmup", Pred("a", "=", 7)), validate=True)
    atom = CmpAtom("t", "a", "eq", 7)
    born = cache.entries[atom.key].born_levels
    assert cache.serve(bk, atom, born + 10) is not None
    assert cache.stats.admit_refreshes == 0


def test_rederive_policy_drops_degraded_entry():
    bk, db = _micro_db()
    cache = WorkloadCache(policy="rederive")
    pl = Planner(db, optimized=True, cache=cache)
    Executor(pl).run(_plan("warmup", Pred("a", "=", 7)), validate=True)
    atom = CmpAtom("t", "a", "eq", 7)
    _degrade(bk, cache.entries[atom.key].blocks, keep_levels=1)
    assert cache.serve(bk, atom, 5) is None
    assert cache.stats.rederives == 1
    assert atom.key not in cache.entries
    # The evaluator transparently re-derives on the next get().
    ev = pl.evaluator()
    blocks = ev.get(atom, 5)
    assert all(bk.levels_left(b) >= 5 for b in blocks)


def test_degraded_entry_never_causes_unpredicted_refresh():
    """End to end: a deeper consumer admitting a degraded cached mask
    pays the refresh AT ADMISSION (accounted as planned), so
    ExecReport.validate's refresh-free contract still holds."""
    bk, db = _micro_db()
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache)
    Executor(pl).run(_plan("warmup", Pred("a", "=", 7)), validate=True)
    atom = CmpAtom("t", "a", "eq", 7)
    _degrade(bk, cache.entries[atom.key].blocks, keep_levels=0)
    deeper = _plan("deeper", And((Pred("a", "=", 7), Pred("b", "=", 3),
                                  Pred("v", "=", 5))))
    ex = Executor(pl)
    got = ex.run(deeper, validate=True)         # must not raise
    r = ex.report
    assert r.cache_admit_refreshes > 0, "admission must have refreshed"
    assert r.refreshes - r.cache_admit_refreshes <= 0
    assert got == run_via_plan(Planner(db, optimized=True), deeper,
                               validate=False)


# ---------------------------------------------------------------------------
# Invalidation on table re-load.
# ---------------------------------------------------------------------------

def test_reload_invalidates_cached_masks():
    bk, db = _micro_db()
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache)
    plan = _plan("q", Pred("a", "=", 7))
    first = Executor(pl).run(plan, validate=True)
    assert len(cache.entries) > 0
    misses0 = cache.stats.misses

    rng = np.random.default_rng(99)
    nrows = 60
    new = {"a": rng.integers(1, 50, nrows), "b": rng.integers(1, 50, nrows),
           "v": rng.integers(1, 20, nrows)}
    db.load_table(db.tables["t"].schema, new, nrows)
    assert cache.stats.invalidations > 0
    assert len(cache.entries) == 0, "stale masks must not survive a reload"

    second = Executor(pl).run(plan, validate=True)
    assert cache.stats.misses > misses0, "reload forces re-derivation"
    exp = {"s": int(new["v"][new["a"] == 7].sum()) % bk.t,
           "n": int((new["a"] == 7).sum()) % bk.t}
    assert second == exp, "post-reload answers must reflect the new data"


def test_reload_only_invalidates_that_table():
    bk, db = _micro_db()
    rng = np.random.default_rng(5)
    db.load_table(TableSchema("u", [ColumnSpec("x", "int")]),
                  {"x": rng.integers(1, 50, 30)}, 30)
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache)
    Executor(pl).run(_plan("qt", Pred("a", "=", 7)), validate=True)
    Executor(pl).run(QueryPlan(name="qu", fact="u", where=Pred("x", "=", 9),
                               aggs=(Agg("count", (), "n"),)), validate=True)
    keys_before = set(cache.entries)
    db.load_table(TableSchema("u", [ColumnSpec("x", "int")]),
                  {"x": rng.integers(1, 50, 30)}, 30)
    assert all(k[0] == "t" for k in cache.entries)
    assert {k for k in keys_before if k[0] == "t"} == set(cache.entries)


# ---------------------------------------------------------------------------
# Cross-query workload scheduling at the paper profile.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def workload(tiny_db, mock_paper):
    """One cold + one warm pass of the full executable mix through
    `run_workload` on a persistent cache."""
    bk = mock_paper
    bk.stats.reset()
    bk.op_log.clear()
    cache = WorkloadCache()
    pl = Planner(tiny_db, optimized=True, cache=cache)
    plans = [Q.QUERIES[qn][0]() for qn in MIX]
    cold = run_workload(pl, plans)
    warm = run_workload(pl, plans)
    bk.stats.reset()
    bk.op_log.clear()
    return {"cold": cold, "warm": warm, "cache": cache}


def test_workload_warm_cold_parity(workload, tiny_db):
    cold, warm = workload["cold"], workload["warm"]
    assert cold.results == warm.results, "warm pass must decrypt identically"
    oracles = [Q.QUERIES[qn][2](tiny_db) for qn in MIX]
    assert cold.results == oracles, "workload results must match the oracle"


def test_workload_counter_accounting(workload):
    cold, warm = workload["cold"], workload["warm"]
    assert cold.cache.hits == 0 and cold.cache.misses > 0
    assert warm.cache.misses == 0, "every warm atom must hit"
    assert warm.cache.hits > 0
    assert warm.hit_rate > 0.5
    # Per-query reports see their own hit counts.
    assert all(r.cache_hits > 0 for r in warm.reports)
    assert all(r.cache_hits == 0 for r in cold.reports)


def test_workload_warm_pass_launches_fewer_circuits(workload):
    cold, warm = workload["cold"], workload["warm"]
    assert warm.launches < cold.launches
    assert warm.muls < cold.muls


def test_workload_fk_bank_reuse(workload):
    """Translated joins (Q12 aux, Q19 hops) reuse the per-key EQ bank
    instead of re-running nparent EQ circuits."""
    cold, warm = workload["cold"], workload["warm"]
    assert cold.cache.fk_misses > 0
    assert warm.cache.fk_misses == 0
    assert warm.cache.fk_hits > 0
