"""End-to-end encrypted analytics on REAL ciphertexts (micro domain,
t=257): load -> WHERE -> aggregate -> GROUP BY -> decrypt, checked
against plaintext, with zero refreshes (the planner's whole point)."""
import numpy as np
import pytest

from repro.engine import ops
from repro.engine.plan import Agg, And, Factor, Pred
from repro.engine.planner import Planner
from repro.engine.schema import ColumnSpec, TableSchema
from repro.engine.storage import Database


@pytest.fixture(scope="module")
def sales_db(bfv_micro):
    """A small sales table with t=257-safe domains."""
    rng = np.random.default_rng(3)
    n = 40
    schema = TableSchema("sales", [
        ColumnSpec("day", "int"),          # 1..100
        ColumnSpec("price", "int"),        # 1..100
        ColumnSpec("qty", "int"),          # 1..10
        ColumnSpec("region", "str"),
    ])
    data = {
        "day": rng.integers(1, 101, n),
        "price": rng.integers(1, 101, n),
        "qty": rng.integers(1, 11, n),
        "region": [["N", "S", "E", "W"][i] for i in rng.integers(0, 4, n)],
    }
    db = Database(bfv_micro)
    db.load_table(schema, data, n)
    return db


def test_select_sum_count_on_real_he(sales_db, bfv_micro):
    bk = bfv_micro
    t = bk.t
    pl = Planner(sales_db, optimized=True)
    tbl = sales_db.tables["sales"]
    plain = sales_db.plain["sales"]
    expr = And((Pred("day", "<", 50), Pred("qty", ">=", 3)))
    mask = pl.where_mask(tbl, expr)
    sel = (plain["day"] < 50) & (plain["qty"] >= 3)

    total = pl.aggregate(tbl, Agg("sum", (Factor("price"),), "s"), mask)
    assert int(bk.decrypt(total)[0]) == int(plain["price"][sel].sum()) % t
    cnt = pl.aggregate(tbl, Agg("count", (), "c"), mask)
    assert int(bk.decrypt(cnt)[0]) == int(sel.sum())
    assert bk.stats.refresh == 0, "optimized plan must stay in budget"


def test_group_by_on_real_he(sales_db, bfv_micro):
    bk = bfv_micro
    t = bk.t
    pl = Planner(sales_db, optimized=True)
    tbl = sales_db.tables["sales"]
    plain = sales_db.plain["sales"]
    rdict = tbl.schema.col("region").dictionary
    res = pl.group_aggregate(tbl, "region", list(rdict.values()),
                             (Agg("sum", (Factor("qty"),), "sq"),), None)
    for name, rid in rdict.items():
        got = int(bk.decrypt(res[rid]["sq"])[0])
        exp = int(plain["qty"][plain["region"] == rid].sum()) % t
        assert got == exp, name


def test_join_translate_on_real_he(sales_db, bfv_micro):
    """Extract+Broadcast+EQ join mask (Fig. 2) on real ciphertexts: a
    4-row dimension table filtering the fact rows."""
    bk = bfv_micro
    rng = np.random.default_rng(4)
    dim_schema = TableSchema("dim", [ColumnSpec("key", "int"),
                                     ColumnSpec("flag", "int")])
    keys = np.arange(1, 5)
    flags = np.array([1, 0, 1, 0])
    db = sales_db
    db.load_table(dim_schema, {"key": keys, "flag": flags}, 4)
    fact_schema = TableSchema("fact", [ColumnSpec("fk", "int"),
                                       ColumnSpec("v", "int")])
    fk = rng.integers(1, 5, 24)
    v = rng.integers(1, 20, 24)
    db.load_table(fact_schema, {"fk": fk, "v": v}, 24)

    from repro.core import compare as cmp
    fact = db.tables["fact"]
    dim_flag = db.tables["dim"].col("flag").blocks[0]
    down = ops.translate_mask_down(bk, dim_flag, fact, "fk", 4)
    got = bk.decrypt(down[0])[:24]
    exp = flags[fk - 1]
    assert np.array_equal(got, exp)
    s = ops.masked_sum(bk, fact.col("v").blocks, down)
    assert int(bk.decrypt(s)[0]) == int(v[exp == 1].sum()) % bk.t
