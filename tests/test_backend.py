"""Mock/BFV backend equivalence: identical op sequences must produce
identical values, op counts and (mock >= conservative) noise accounting."""
import numpy as np
import pytest

from repro.core import compare as cmp
from repro.core.noise import NoiseProfile
from repro.engine.backend import BFVBackend, MockBackend


def test_same_results_same_opcounts(bfv_micro, micro_params):
    bkr = bfv_micro
    bkm = MockBackend(NoiseProfile(n=micro_params.n, t=micro_params.t,
                                   k=micro_params.k))
    rng = np.random.default_rng(7)
    vals = rng.integers(0, 257, 16)
    xr, xm = bkr.encrypt(vals), bkm.encrypt(vals)
    br0, bm0 = bkr.stats.clone(), bkm.stats.clone()

    def circuit(bk, x):
        m1 = cmp.eq_scalar(bk, x, int(vals[0]))
        m2 = cmp.lt_scalar(bk, x, 100)
        m = cmp.and_(bk, m1, cmp.not_(bk, m2))
        return bk.sum_slots(m)

    rr = bkr.decrypt(circuit(bkr, xr))
    rm = bkm.decrypt(circuit(bkm, xm))
    assert np.array_equal(rr[:16], rm[:16])
    for f in ("mul", "mul_scalar", "add"):
        assert getattr(bkr.stats, f) - getattr(br0, f) == \
            getattr(bkm.stats, f) - getattr(bm0, f), f


def test_refresh_inplace_visible_to_all_references():
    bk = MockBackend()
    x = bk.encrypt(np.arange(8))
    y = x                        # second DAG edge to the same value
    x.noise = -5.0               # nearly exhausted
    bk.ensure_levels(x, 3)
    assert bk.stats.refresh == 1
    assert y.noise == bk.model.fresh(), "refresh must be visible via all refs"


def test_auto_refresh_counts_and_correctness():
    bk = MockBackend()
    x = bk.encrypt(np.array([3]))
    y = bk.encrypt(np.array([5]))
    x.noise = -10.0
    y.noise = -10.0
    z = bk.mul(x, y)             # must refresh, not corrupt
    assert int(bk.decrypt(z)[0]) == 15
    assert bk.stats.refresh >= 1


def test_budget_exhaustion_raises_when_auto_refresh_off():
    bk = MockBackend()
    bk.auto_refresh = False
    x = bk.encrypt(np.array([3]))
    x.noise = -1.0
    with pytest.raises(RuntimeError, match="budget exhausted"):
        bk.mul(x, x)


def test_dot_plain_matches_sequence():
    bk = MockBackend()
    rng = np.random.default_rng(1)
    cts = [bk.encrypt(rng.integers(0, bk.t, 32)) for _ in range(9)]
    coeffs = rng.integers(0, bk.t, 9)
    fast = bk.decrypt(bk.dot_plain(cts, coeffs))
    slow = np.zeros(bk.slots, dtype=np.int64)
    for c, ct in zip(coeffs, cts):
        slow = (slow + c * ct.vec) % bk.t
    assert np.array_equal(fast, slow)


def test_broadcast_slot(bfv_micro):
    bk = bfv_micro
    vals = np.arange(10, 26)
    x = bk.encrypt(vals)
    got = bk.decrypt(bk.broadcast_slot(x, 3))
    assert np.all(got == vals[3])
