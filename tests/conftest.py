"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the host's real
single device; only launch/dryrun.py (its own process) forces 512."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def micro_params():
    """t=257 (Fermat prime), n=128: full comparison circuits fit fast."""
    from repro.core.params import make_params
    return make_params(n=128, t=257, k=12)


@pytest.fixture(scope="session")
def tiny_params():
    """t=7681, n=256: the generic (non-Fermat) exponent path."""
    from repro.core.params import test_params
    return test_params()


@pytest.fixture(scope="session")
def bfv_micro(micro_params):
    from repro.engine.backend import BFVBackend
    return BFVBackend(micro_params, seed=11)


@pytest.fixture(scope="session")
def mock_paper():
    from repro.engine.backend import MockBackend
    return MockBackend()


@pytest.fixture(scope="session")
def tiny_db(mock_paper):
    from repro.engine import tpch
    return tpch.load(mock_paper, tpch.Scale.tiny())


@pytest.fixture(autouse=True)
def _reset_stats(request):
    yield
    for name in ("bfv_micro", "mock_paper"):
        if name in request.fixturenames:
            bk = request.getfixturevalue(name)
            bk.stats.reset()
            bk.op_log.clear()
            bk.refresh_log.clear()
