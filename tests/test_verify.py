"""Static plan verifier (engine/verify.py, DESIGN §10).

Three contract families:

  * positive: every shipped TPC-H DAG verifies clean in both regimes,
    verification never touches a real ciphertext, and the static
    headroom at each decrypt boundary is sound (<= runtime-observed).
  * negative: seeded plan mutations — dropped refresh sizing, deepened
    subtrees, aliased cache entries, misplaced limb shards — are each
    rejected statically, before any ciphertext op runs.
  * plumbing: the opt-out knob, skip classification for non-lowerable
    plans, and the pure dead-refresh analysis.
"""
import dataclasses

import numpy as np
import pytest

from repro.engine import queries as Q
from repro.engine.executor import Executor, run_via_plan
from repro.engine.physical import MaskNode, annotate_downstream
from repro.engine.plan import Agg, And, Or, Pred, QueryPlan
from repro.engine.planner import Planner
from repro.engine.sharded import ShardContext, lint_shard_context
from repro.engine.verify import (PlanVerificationError, _dead_refresh_ids,
                                 verify_compiled, verify_plan)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PORTED = list(Q.PLAN_EXECUTABLE)

# Every code a mutation may legitimately surface as; anything outside
# this set is a verifier bug, not a detection.
MUTATION_CODES = {"noise.exhausted", "refresh.unplanned", "refresh.unpredicted",
                  "depth.over", "depth.under", "ir.levels", "cache.alias",
                  "mesh.limbs", "mesh.ring", "mesh.pad", "mesh.data",
                  "mesh.model", "mesh.ledger", "ir.shape"}


def _codes(findings):
    return {f.code for f in findings}


def _find(node, kind):
    if node.kind == kind:
        return node
    for c in node.children:
        got = _find(c, kind)
        if got is not None:
            return got
    return None


# ---------------------------------------------------------------------------
# Positive sweep: shipped plans verify clean, purely.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("optimized", [True, False])
@pytest.mark.parametrize("qn", PORTED)
def test_shipped_plans_verify_clean(tiny_db, qn, optimized):
    pl = Planner(tiny_db, optimized=optimized, verify=False)
    rep = pl.verify(Q.QUERIES[qn][0]())
    assert not rep.skipped
    assert rep.ok, [str(f) for f in rep.errors]
    assert rep.decrypts, "every shipped plan decrypts at least once"
    assert all(d["headroom"] > 0 for d in rep.decrypts)


@pytest.mark.parametrize("qn", ["Q12", "Q19"])
def test_verification_touches_no_ciphertexts(tiny_db, mock_paper, qn):
    """The purity contract: a verify pass leaves the real backend's
    OpStats, refresh log and cache bit-identical."""
    bk = mock_paper
    pl = Planner(tiny_db, optimized=False, verify=False)
    before = dataclasses.asdict(bk.stats)
    logs = len(bk.refresh_log)
    entries = dict(pl.mask_cache.entries)
    rep = pl.verify(Q.QUERIES[qn][0]())
    assert rep.ok
    assert dataclasses.asdict(bk.stats) == before
    assert len(bk.refresh_log) == logs
    assert pl.mask_cache.entries == entries


def test_crosscheck_static_headroom_is_sound(tiny_db):
    """Auto-verification + post-run crosscheck: the abstract trajectory
    mirrors the mock backend op-for-op, so static headroom matches the
    runtime-observed headroom at every decrypt boundary."""
    pl = Planner(tiny_db, optimized=True)
    assert pl.verify_plans
    ex = Executor(pl)
    ex.run(Q.QUERIES["Q6"][0]())
    rep = ex._verify_report
    assert rep is not None and rep.ok
    obs = ex.report.decrypt_headrooms
    assert len(obs) == len(rep.decrypts) == 1
    static = [d["headroom"] for d in rep.decrypts]
    assert all(s <= o + 1e-6 for s, o in zip(static, obs))
    assert np.allclose(static, obs), (static, obs)
    # ...and the crosscheck rejects an execution that observed *less*
    # headroom than proven (an under-approximating abstract model).
    ex.report.decrypt_headrooms = [obs[0] - 1.0]
    with pytest.raises(AssertionError, match="under-approximated"):
        rep.crosscheck(ex.report)


def test_verify_opt_out_knob(tiny_db):
    pl = Planner(tiny_db, optimized=True, verify=False)
    ex = Executor(pl)
    ex.run(Q.QUERIES["Q6"][0]())
    assert ex._verify_report is None
    # per-call override beats the planner default in both directions
    run_via_plan(pl, Q.QUERIES["Q6"][0](), verify=True)
    assert pl.verify_plans is False, "override must not stick"


@pytest.mark.parametrize("qn,code", [("Q4", "ir.correlated"),
                                     ("Q5", "ir.unsupported")])
def test_non_lowerable_plans_are_skipped_not_failed(tiny_db, qn, code):
    rep = Planner(tiny_db, optimized=True, verify=False).verify(
        Q.QUERIES[qn][0]())
    assert rep.skipped
    assert code in _codes(rep.findings)
    assert not rep.errors


# ---------------------------------------------------------------------------
# Negative: seeded mutations are rejected statically.
# ---------------------------------------------------------------------------

def test_dropped_refresh_sizing_fails_ir_typing(tiny_db):
    """Zeroing a translated node's downstream_muls (what a dropped
    planned-refresh annotation looks like) violates the scheduler
    recurrence the verifier re-derives."""
    pl = Planner(tiny_db, optimized=True, verify=False)
    cq = Executor(pl).compile(Q.QUERIES["Q19"][0]())
    node = _find(cq.where_node, "translated")
    assert node is not None and node.downstream_muls > 0
    node.downstream_muls = 0
    rep = verify_compiled(pl, cq)
    assert "ir.levels" in _codes(rep.errors), [str(f) for f in rep.findings]


def test_deepened_subtree_fails_noise_or_depth(tiny_db):
    """Grafting 8 extra conjunction layers onto Q6's WHERE blows the
    depth/noise envelope; the verifier must reject it before execution
    even though the annotations are self-consistent."""
    pl = Planner(tiny_db, optimized=True, verify=False)
    cq = Executor(pl).compile(Q.QUERIES["Q6"][0]())
    root = cq.where_node
    for _ in range(8):
        root = MaskNode("and", root.table,
                        children=[root, cq.where_node.clone()])
    annotate_downstream(root, cq.inject_layers)
    cq.where_node = root
    rep = verify_compiled(pl, cq)
    assert rep.errors
    codes = _codes(rep.errors)
    assert codes & {"noise.exhausted", "depth.over", "refresh.unplanned",
                    "refresh.unpredicted"}, codes
    assert codes <= MUTATION_CODES, codes


@pytest.fixture
def alias_setup(tiny_db):
    """A warm cache whose shared entry was tampered to serve at born
    level 0 with near-exhausted noise — the PR 6 reconstruction: the
    first product refreshes the served blocks in place under every
    consumer holding them."""
    p = Pred("l_shipmode", "=", "MAIL")
    q = Pred("l_quantity", "<", 25)
    plan = QueryPlan(name="alias", fact="lineitem",
                     where=And((p, Or((p, q)))),
                     aggs=(Agg("count", (), "n"),))
    pl = Planner(tiny_db, optimized=True, verify=False)
    Executor(pl).run(plan, validate=True)          # warm the cache
    assert pl.mask_cache.entries
    for entry in pl.mask_cache.entries.values():
        entry.born_levels = 0
        for b in entry.blocks:
            b.noise = -1.5        # serves as-is, exhausts on first product
    return pl, plan


def test_aliased_cache_refresh_detected_statically(alias_setup):
    pl, plan = alias_setup
    rep = verify_plan(pl, plan)
    assert "cache.alias" in _codes(rep.errors), [str(f) for f in rep.findings]
    hits = [f for f in rep.errors if f.code == "cache.alias"]
    assert any("served to 2 consumers" in f.detail for f in hits), hits


def test_admission_raises_before_any_ciphertext_op(alias_setup, mock_paper):
    """End to end: Executor.run refuses the poisoned-cache plan at
    admission — typed error, zero real ops."""
    pl, plan = alias_setup
    pl.verify_plans = True
    before = dataclasses.asdict(mock_paper.stats)
    with pytest.raises(PlanVerificationError, match="cache.alias"):
        Executor(pl).run(plan)
    assert dataclasses.asdict(mock_paper.stats) == before


def test_misplaced_limb_shard_rejected(tiny_db, mock_paper):
    pl = Planner(tiny_db, optimized=True, verify=False)
    pl.shard_ctx = ShardContext(2, limb_shards=1,
                                limbs=mock_paper.limbs + 1,
                                ring_n=mock_paper.slots)
    rep = pl.verify(Q.QUERIES["Q6"][0]())
    assert "mesh.limbs" in _codes(rep.errors)


def test_limb_padding_rule_linted():
    class _FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}

    ctx = ShardContext(2, mesh=_FakeMesh(), limb_shards=4, limbs=30,
                       ring_n=64)
    codes = {c for c, _ in lint_shard_context(ctx, limbs=30, ring_n=64)}
    assert "mesh.pad" in codes          # 30 % 4 != 0 without padding
    ok = ShardContext(2, mesh=_FakeMesh(), limb_shards=4, limbs=32,
                      ring_n=64)
    assert lint_shard_context(ok, limbs=32, ring_n=64) == []


# ---------------------------------------------------------------------------
# Dead-refresh analysis (pure).
# ---------------------------------------------------------------------------

def _ev(eid, kind="planned", admission=False):
    return {"id": eid, "kind": kind, "admission": admission,
            "what": f"planned(levels=9)#{eid}", "stage": "where"}


def test_dead_refresh_flagged_when_counterfactual_clears():
    events = [_ev(0)]
    decrypts = [{"sites": {0}, "headroom_nr": 5.0}]
    assert _dead_refresh_ids(events, decrypts) == [0]


def test_needed_refresh_not_flagged():
    events = [_ev(0)]
    decrypts = [{"sites": {0}, "headroom_nr": -3.0}]
    assert _dead_refresh_ids(events, decrypts) == []


def test_refresh_needed_by_any_decrypt_survives():
    events = [_ev(0)]
    decrypts = [{"sites": {0}, "headroom_nr": 5.0},
                {"sites": {0}, "headroom_nr": -0.1}]
    assert _dead_refresh_ids(events, decrypts) == []


def test_auto_refresh_poisons_the_counterfactual():
    events = [_ev(0), _ev(1, kind="auto")]
    decrypts = [{"sites": {0}, "headroom_nr": 5.0}]
    assert _dead_refresh_ids(events, decrypts) == []


def test_admission_and_unseen_refreshes_ignored():
    events = [_ev(0, admission=True), _ev(1)]
    decrypts = [{"sites": {0}, "headroom_nr": 5.0}]
    assert _dead_refresh_ids(events, decrypts) == []


# ---------------------------------------------------------------------------
# Hypothesis fuzz (optional dependency; skipped when absent).
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(layers=st.integers(min_value=0, max_value=6))
    def test_fuzz_deepen_never_crashes_verifier(tiny_db, layers):
        pl = Planner(tiny_db, optimized=True, verify=False)
        cq = Executor(pl).compile(Q.QUERIES["Q6"][0]())
        root = cq.where_node
        for _ in range(layers):
            root = MaskNode("and", root.table,
                            children=[root, cq.where_node.clone()])
        annotate_downstream(root, cq.inject_layers)
        cq.where_node = root
        rep = verify_compiled(pl, cq)
        assert "verify.crash" not in _codes(rep.findings)
        assert _codes(rep.errors) <= MUTATION_CODES

    @settings(max_examples=8, deadline=None)
    @given(delta=st.integers(min_value=1, max_value=7))
    def test_fuzz_annotation_tamper_always_detected(tiny_db, delta):
        pl = Planner(tiny_db, optimized=True, verify=False)
        cq = Executor(pl).compile(Q.QUERIES["Q19"][0]())
        node = _find(cq.where_node, "translated")
        node.downstream_muls += delta
        rep = verify_compiled(pl, cq)
        assert "ir.levels" in _codes(rep.errors)
