"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, prefill/decode consistency,
and full-config parameter counts near their nominal sizes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import lm
from repro.train import steps as steps_mod

NOMINAL_B = {
    "mamba2-1.3b": 1.3, "recurrentgemma-9b": 9.0, "phi3.5-moe-42b": 42.0,
    "deepseek-v2-236b": 236.0, "phi-3-vision-4.2b": 4.2, "gemma3-27b": 27.0,
    "qwen2-72b": 72.0, "starcoder2-3b": 3.0, "gemma2-27b": 27.0,
    "whisper-large-v3": 1.55,
}


def _batch(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(key, (B, 8, cfg.d_model), jnp.float32)
    if cfg.is_enc_dec:
        batch["enc_embeds"] = jax.random.normal(key, (B, 16, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg, jnp.float32)
    B, S = 2, 32
    batch = _batch(cfg, B, S, key)
    logits, _ = lm.forward(params, cfg, tokens=batch["tokens"],
                           patches=batch.get("patches"),
                           enc_embeds=batch.get("enc_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    step = steps_mod.make_train_step(cfg, lr=1e-3)
    opt = steps_mod.init_opt(cfg, params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2["adam"]["step"]) == 1
    # parameters actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:-1]), x[-1]) must match forward(x) at the last
    position — the KV-cache/state machinery is exact, not approximate."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg, jnp.float32)
    B, S = 2, 24
    batch = _batch(cfg, B, S, key)
    toks = batch["tokens"]
    kw = {k: batch[k] for k in ("patches", "enc_embeds") if k in batch}

    full_logits, _ = lm.forward(params, cfg, tokens=toks, **kw)

    cache0 = lm.make_cache(cfg, B, 0, jnp.float32)
    kw_p = dict(kw)
    _, caches = lm.forward(params, cfg, tokens=toks[:, :-1], caches=cache0, **kw_p)
    kw_d = {k: v for k, v in kw.items() if k != "patches"}
    dec_logits, _ = lm.forward(params, cfg, tokens=toks[:, -1:], caches=caches,
                               pos=S - 1, **kw_d)
    if cfg.frontend == "vision":
        pytest.skip("vision prefix makes last-token comparison position-dependent")
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_param_count_near_nominal(arch):
    n = lm.param_count(get_config(arch)) / 1e9
    nom = NOMINAL_B[arch]
    assert 0.75 * nom <= n <= 1.35 * nom, (arch, n, nom)


def test_local_window_cache_is_bounded():
    """gemma2-style local layers must cap their cache at the window."""
    cfg = get_smoke_config("gemma2-27b")
    key = jax.random.PRNGKey(2)
    params = lm.init_params(key, cfg, jnp.float32)
    B, S = 1, 64  # window is 16
    cache0 = lm.make_cache(cfg, B, 0, jnp.float32)
    _, caches = lm.forward(params, cfg,
                           tokens=jax.random.randint(key, (B, S), 0, cfg.vocab),
                           caches=cache0)
    local_k = caches["units"][0]["k"]     # slot 0 = local
    global_k = caches["units"][1]["k"]    # slot 1 = global
    assert local_k.shape[2] == cfg.window
    assert global_k.shape[2] == S
