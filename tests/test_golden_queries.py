"""Golden regressions for engine/queries.py: Q1 and Q6 on MockBackend.

These pin the two paper-anchored scan queries to their plaintext oracles
with *exact* mod-t equality, and assert the optimized planner runs them
with zero refresh (bootstrap) events — the paper's headline claim and
the invariant the batched evaluation path must preserve.
"""
import pytest

from repro.engine import queries as Q
from repro.engine.planner import Planner


@pytest.fixture(scope="module")
def planner(tiny_db):
    return Planner(tiny_db, optimized=True)


@pytest.mark.parametrize("qn", ["Q1", "Q6"])
def test_golden_query_exact_and_refresh_free(planner, tiny_db, mock_paper, qn):
    _, run_f, oracle_f = Q.QUERIES[qn]
    bk = mock_paper
    bk.stats.reset()
    bk.refresh_log.clear()
    got = run_f(planner)
    exp = oracle_f(tiny_db)
    assert got == exp, f"{qn}: encrypted result != plaintext oracle (mod t)"
    assert bk.stats.refresh == 0, (
        f"{qn}: optimized plan paid {bk.stats.refresh} refreshes "
        f"({bk.refresh_log})")


def test_golden_q6_parameter_sweep(planner, tiny_db, mock_paper):
    """Q6 with shifted predicate constants stays oracle-exact."""
    bk = mock_paper
    bk.stats.reset()
    got = Q.run_q6(planner, year=1995, disc=(0.04, 0.06), qty=30)
    exp = Q.oracle_q6(tiny_db, year=1995, disc=(0.04, 0.06), qty=30)
    assert got == exp
    assert bk.stats.refresh == 0


def test_golden_q1_decrypt_counts(planner, tiny_db, mock_paper):
    """Q1 group COUNTs across the full group grid reconcile with the
    table's row count (every row lands in exactly one group)."""
    got = Q.run_q1(planner)
    sel = tiny_db.plain["lineitem"]["l_shipdate"] <= Q.D("1998-09-02")
    assert sum(row["count_order"] for row in got.values()) == int(sel.sum())
