"""Repo AST lint (tools/lint_rules.py) + calibration-schema guards
(benchmarks/common.py) — the two satellite static checks of DESIGN §10.
"""
import json
import pathlib
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))
sys.path.insert(0, str(ROOT))

import lint_rules  # noqa: E402

from benchmarks import common  # noqa: E402


def _lint_src(tmp_path, src, name="engine_mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return lint_rules.lint_file(str(p))


# ---------------------------------------------------------------------------
# R001: raw jnp modular arithmetic outside the dispatch layers.
# ---------------------------------------------------------------------------

def test_r001_fires_on_raw_jnp_mod(tmp_path):
    out = _lint_src(tmp_path, """\
        import jax.numpy as jnp

        def bad(x, q):
            return jnp.sum(x) % q
    """)
    assert [f[0] for f in out] == ["R001"]


def test_r001_allows_the_modular_layers(tmp_path):
    layer = tmp_path / "core"
    layer.mkdir()
    p = layer / "limbops.py"
    p.write_text("import jax.numpy as jnp\n\ndef ok(x, q):\n"
                 "    return jnp.add(x, x) % q\n")
    assert lint_rules.lint_file(str(p)) == []


def test_r001_ignores_plain_python_mod(tmp_path):
    assert _lint_src(tmp_path, "def ok(a, b):\n    return a % b\n") == []


# ---------------------------------------------------------------------------
# R002: int64 multiply without an overflow-guard note.
# ---------------------------------------------------------------------------

def test_r002_fires_on_unguarded_int64_mul(tmp_path):
    out = _lint_src(tmp_path, """\
        import numpy as np

        def bad(a, b):
            return (a * b).astype(np.int64)
    """)
    assert [f[0] for f in out] == ["R002"]


@pytest.mark.parametrize("guard", [
    "# products < 2^34, exact int64",
    "# stays below overflow",
    "# fits int64",
])
def test_r002_suppressed_by_line_comment(tmp_path, guard):
    out = _lint_src(tmp_path, f"""\
        import numpy as np

        def ok(a, b):
            {guard}
            return (a * b).astype(np.int64)
    """)
    assert out == []


def test_r002_suppressed_by_docstring_guard(tmp_path):
    out = _lint_src(tmp_path, '''\
        import numpy as np

        def ok(a, b):
            """Operands are 16-bit, so products < 2^34 — exact int64."""
            return (a * b).astype(np.int64)
    ''')
    assert out == []


def test_r002_ignores_mul_without_int64(tmp_path):
    assert _lint_src(tmp_path, "def ok(a, b):\n    return a * b\n") == []


# ---------------------------------------------------------------------------
# The repo itself must be clean (same invocation as the CI job).
# ---------------------------------------------------------------------------

def test_repo_is_lint_clean():
    findings = lint_rules.lint_paths([str(ROOT / "src" / "repro")])
    assert findings == [], "\n".join(
        f"{p}:{ln}: {c} {m}" for c, p, ln, m in findings)


# ---------------------------------------------------------------------------
# op_costs calibration schema: fail loudly, never mis-price.
# ---------------------------------------------------------------------------

GOOD = {"n": 1024, "k": 8, "mul": 1.0, "mul_plain": 0.5, "mul_scalar": 0.2,
        "add": 0.1, "rotate": 0.8, "refresh": 44.0}


@pytest.fixture
def costs_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS", str(tmp_path))
    common._calibration.cache_clear()
    common.paper_costs.cache_clear()
    yield tmp_path
    common._calibration.cache_clear()
    common.paper_costs.cache_clear()


def _write(costs_dir, d):
    (costs_dir / "op_costs.json").write_text(json.dumps(d))


def test_unknown_calibration_key_raises(costs_dir):
    _write(costs_dir, {**GOOD, "mull": 2.0})      # typo'd op name
    with pytest.raises(ValueError, match=r"unknown keys \['mull'\]"):
        common.op_costs()


def test_missing_calibration_key_raises(costs_dir):
    bad = dict(GOOD)
    del bad["rotate"]
    _write(costs_dir, bad)
    with pytest.raises(ValueError, match=r"missing keys \['rotate'\]"):
        common.op_costs()


def test_gather_byte_is_a_permitted_extra(costs_dir):
    _write(costs_dir, {**GOOD, "gather_byte": 3.25e-11})
    d = common.op_costs()
    assert d["gather_byte"] == 3.25e-11
    assert d["mul"] > 0


def test_gather_byte_defaults_to_engine_constant(costs_dir):
    from repro.engine.sharded import GATHER_BYTE_SECONDS
    _write(costs_dir, GOOD)
    assert common.op_costs()["gather_byte"] == GATHER_BYTE_SECONDS
