"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = collective_bytes(per-device) / link_bw

Hardware: TPU v5e-like — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.  XLA's cost_analysis on the partitioned module is
already per-device; collective bytes parsed from the optimized HLO are
per-device payloads.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (serve) with N_active for MoE;
the ratio MODEL_FLOPS / (HLO_FLOPs x chips) flags remat/redundancy waste
(XLA counts dots as MACs on CPU, so a ratio near 2.0 is "clean").
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(arch: str, shape: str) -> float:
    """Analytic useful-FLOPs for one step of the cell (whole job)."""
    from repro.configs import get_config
    from repro.configs.registry import SHAPES
    from repro.models.lm import param_count

    if arch == "nshedb":
        # modmul count model (Table 3): per block, (eq_levels + 1) ct-muls
        # x 3 limb-products x k^2-ish keyswitch + rotations; count the
        # dominant barrett muls: per ct-op ~ (3k + k^2) * n lane-muls.
        from repro.configs.nshedb import CONFIG, SHAPES as NSH
        k, n = CONFIG.k, CONFIG.n
        nblocks = NSH[shape]["nblocks"]
        ct_ops = CONFIG.eq_levels + 1 + CONFIG.rot_steps
        lane_muls = nblocks * ct_ops * (3 * k + k * k) * n
        return lane_muls * 2.0          # mul+add per lane FMA-equivalent

    cfg = get_config(arch)
    info = SHAPES[shape]
    tokens = info["seq"] * info["batch"] if info["kind"] != "decode" \
        else info["batch"]
    n_active = cfg.active_param_count() if cfg.is_moe else param_count(cfg)
    per_tok = 6 * n_active if info["kind"] == "train" else 2 * n_active
    return float(per_tok) * tokens


def load_cells() -> list[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def analyze(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return {"arch": cell["arch"], "shape": cell["shape"],
                "mesh": cell["mesh"], "status": cell.get("error", "fail")[:60]}
    chips = 1
    for d in cell["mesh_shape"]:
        chips *= d
    t_comp = cell["flops"] / PEAK_FLOPS
    t_mem = cell["hlo_bytes"] / HBM_BW
    t_coll = cell["collective_total"] / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cell["arch"], cell["shape"])
    # analytic useful-compute time per chip (XLA's cost_analysis counts a
    # while-loop body ONCE, so scanned-layer models under-report; this
    # column is the loop-corrected term the §Perf discussion uses).
    t_model = mf / (chips * PEAK_FLOPS)
    ratio = mf / (cell["flops"] * chips) if cell["flops"] > 0 else 0.0
    bound = max(t_comp, t_mem, t_coll, t_model)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": f"{t_comp:.2e}", "t_memory_s": f"{t_mem:.2e}",
        "t_collective_s": f"{t_coll:.2e}", "t_model_s": f"{t_model:.2e}",
        "dominant": dom,
        "roofline_frac": round(t_model / bound, 3) if bound else 0.0,
        "model/hlo_flops": round(ratio, 2),
        "peak_GiB": round(cell["peak_bytes"] / 2**30, 2),
        "fits_16GiB": cell["peak_bytes"] < 16 * 2**30,
    }


def main(quick: bool = False) -> str:
    from .common import save_json, table
    cells = load_cells()
    rows = [analyze(c) for c in cells]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r.get("mesh", ""), r.get("arch", ""), r.get("shape", "")))
    save_json("roofline.json", rows)
    singles = [r for r in rows if r.get("mesh") == "single"]
    multis = [r for r in rows if r.get("mesh") == "multi"]
    out = table(singles, "Roofline — single pod (16x16 = 256 chips)")
    out += "\n" + table(multis, "Roofline — multi pod (2x16x16 = 512 chips)")
    return out


if __name__ == "__main__":
    print(main())
