"""Sharded scan execution at SF=1.0 (engine/sharded.py, DESIGN §4).

Weak/strong scaling of the data-parallel block scan on the mock backend
at the paper parameter profile (n=32768, t=65537, k=30).  SF=1.0
lineitem is 6,001,215 rows = 184 ciphertext blocks per column; every
query runs once per shard count with a fresh `Planner(db, shards=s)`,
decrypted results are asserted identical across shard counts AND against
the plaintext oracle, and the ShardContext ledger prices each run with
the measured per-op costs (results/op_costs.json extrapolated to paper
parameters) — distributed scan lanes divide by the shard count,
replicated singleton work and the psum combine tree do not.

Two query arms, both EQ-only so a single host can execute the full
SF=1.0 ciphertext arithmetic in-process:

  grouped   GROUP BY l_returnflag with the IN pushdown (3 EQ circuits
            over 184 blocks) + SUM(qty), SUM(price), COUNT
  filtered  WHERE l_shipmode IN (1,2) AND l_returnflag = 1,
            SUM(l_quantity)

Emits results/sharded_scan.json.  Full mode asserts the §5 acceptance
bar: > 1.5x modeled speedup at 4 shards; smoke mode (--smoke / quick)
runs 8 blocks at shards (1, 2) and asserts speedup >= 1.

`--limb-shards M` additionally sweeps the model (RNS limb) axis of the
2-D mesh on the filtered arm — limb-local ops divide by the limb
factor, the all-gathered key-switch digits are charged per byte — and
emits results/limb_sharding.json (speedup > 1 required at M=2, >= 1 in
smoke mode).
"""
from __future__ import annotations

import time

import numpy as np

from repro.engine.backend import MockBackend
from repro.engine.executor import run_via_plan
from repro.engine.plan import Agg, And, Factor, Pred, QueryPlan
from repro.engine.planner import Planner
from repro.engine.schema import ColumnSpec, TableSchema
from repro.engine.storage import Database

from .common import fmt_s, op_costs, save_json, table

SF1_ROWS = 6_001_215          # TPC-H lineitem at scale factor 1.0
T = 65537


def _lineitem_db(bk, nrows: int, seed: int = 3) -> tuple[Database, dict]:
    """Integer-coded lineitem slice: enough columns for the two arms.
    Dictionary encoding 6M strings would dominate setup, so categorical
    columns are generated directly as their dictionary ids."""
    rng = np.random.default_rng(seed)
    schema = TableSchema("lineitem", [
        ColumnSpec("l_returnflag", "int"),     # 1..3  (A/N/R)
        ColumnSpec("l_shipmode", "int"),       # 1..7
        ColumnSpec("l_quantity", "int"),       # 1..50
        ColumnSpec("l_extendedprice", "int"),  # fixed-point, < t/2
    ])
    data = {
        "l_returnflag": rng.integers(1, 4, nrows),
        "l_shipmode": rng.integers(1, 8, nrows),
        "l_quantity": rng.integers(1, 51, nrows),
        "l_extendedprice": rng.integers(100, 1000, nrows),
    }
    db = Database(bk)
    db.load_table(schema, data, nrows)
    return db, data


def _arms() -> list[QueryPlan]:
    grouped = QueryPlan(
        "sf1_grouped", "lineitem",
        where=Pred("l_returnflag", "in", (1, 2, 3)),
        group_by="l_returnflag", group_domain=3,
        aggs=(Agg("sum", (Factor("l_quantity"),), "sum_qty"),
              Agg("sum", (Factor("l_extendedprice"),), "sum_price"),
              Agg("count", (), "count")))
    filtered = QueryPlan(
        "sf1_filtered", "lineitem",
        where=And((Pred("l_shipmode", "in", (1, 2)),
                   Pred("l_returnflag", "=", 1))),
        aggs=(Agg("sum", (Factor("l_quantity"),), "sum_qty"),))
    return [grouped, filtered]


def _oracle(plan: QueryPlan, data: dict):
    if plan.name == "sf1_grouped":
        return {v: {"sum_qty": int(data["l_quantity"][data["l_returnflag"] == v].sum() % T),
                    "sum_price": int(data["l_extendedprice"][data["l_returnflag"] == v].sum() % T),
                    "count": int((data["l_returnflag"] == v).sum() % T)}
                for v in (1, 2, 3)}
    keep = np.isin(data["l_shipmode"], (1, 2)) & (data["l_returnflag"] == 1)
    return {"sum_qty": int(data["l_quantity"][keep].sum() % T)}


def _check_same(a, b, where: str) -> None:
    assert a == b, f"sharded result mismatch ({where}): {a} != {b}"


def _run_arm(db, data, plan, shard_counts, costs) -> list[dict]:
    """One strong-scaling curve: same table, rising shard count."""
    rows, base = [], None
    oracle = _oracle(plan, data)
    for s in shard_counts:
        pl = Planner(db, shards=s)
        db.bk.stats.reset()
        t0 = time.time()
        got = run_via_plan(pl, plan)
        wall = time.time() - t0
        _check_same(got, oracle, f"{plan.name} @ {s} vs oracle")
        if base is None:
            base = got
        _check_same(got, base, f"{plan.name} @ {s} vs 1 shard")
        ctx = pl.shard_ctx
        modeled = ctx.modeled_seconds(costs)
        rows.append({
            "query": plan.name, "shards": s,
            "nblocks": db.tables["lineitem"].nblocks,
            "modeled_s": round(modeled, 2),
            "dist_units": sum(ctx.dist.values()),
            "repl_units": sum(ctx.repl.values()),
            "folds": ctx.folds, "mock_wall_s": round(wall, 2),
        })
    t1 = rows[0]["modeled_s"]
    for r in rows:
        r["speedup"] = round(t1 / r["modeled_s"], 2)
    return rows


def _weak_scaling(bk, shard_counts, costs, blocks_per_shard: int) -> list[dict]:
    """Fixed work per shard: table grows with the shard count, so the
    modeled time should stay ~flat (the replicated tail is the
    Amdahl floor)."""
    plan = _arms()[1]
    rows = []
    for s in shard_counts:
        nrows = blocks_per_shard * s * bk.slots - 7     # uneven tail block
        db, data = _lineitem_db(bk, nrows)
        pl = Planner(db, shards=s)
        got = run_via_plan(pl, plan)
        _check_same(got, _oracle(plan, data), f"weak @ {s}")
        rows.append({
            "shards": s, "nblocks": db.tables["lineitem"].nblocks,
            "modeled_s": round(pl.shard_ctx.modeled_seconds(costs), 2),
        })
    return rows


def _limb_sweep(db, data, costs, limb_shards: int, quick: bool) -> list[dict]:
    """Model-axis strong scaling: same table, the k RNS limbs split over
    M devices.  Decrypt must stay byte-identical at every M (the gather
    key-switch preserves the summation order exactly); the ledger prices
    limb-local work at 1/limb_factor and charges the all-gathered
    key-switch digits at gather_byte * (M-1)/M per byte."""
    plan = _arms()[1]                      # filtered arm: cheapest scan
    oracle = _oracle(plan, data)
    sweep = sorted({1, 2, limb_shards} & set(range(1, limb_shards + 1)))
    rows, base = [], None
    for m in sweep:
        pl = Planner(db, shards=1, limb_shards=m)
        got = run_via_plan(pl, plan)
        _check_same(got, oracle, f"limb sweep @ {m} vs oracle")
        if base is None:
            base = got
        _check_same(got, base, f"limb sweep @ {m} vs limb_shards=1")
        ctx = pl.shard_ctx
        rows.append({
            "limb_shards": m,
            "modeled_s": round(ctx.modeled_seconds(costs), 2),
            "limb_factor": ctx.limb_factor(),
            "gathers": ctx.gathers,
            "gather_bytes": int(ctx.gather_bytes),
            "limb_local_bytes": int(ctx.limb_local_bytes),
        })
    t1 = rows[0]["modeled_s"]
    for r in rows:
        r["speedup"] = round(t1 / r["modeled_s"], 2)
    return rows


def main(quick: bool = False, limb_shards: int | None = None) -> str:
    bk = MockBackend()
    costs = op_costs(quick)
    shard_counts = (1, 2) if quick else (1, 2, 4, 8)
    nrows = 8 * bk.slots - 1000 if quick else SF1_ROWS
    db, data = _lineitem_db(bk, nrows)

    strong = []
    for plan in _arms():
        strong += _run_arm(db, data, plan, shard_counts, costs)

    weak = _weak_scaling(bk, shard_counts, costs,
                         blocks_per_shard=2 if quick else 23)

    # Uneven tables pad to the shard multiple and stay byte-identical:
    # 6 blocks at 4 shards -> 8 physical lanes.
    pad_db, pad_data = _lineitem_db(bk, 6 * bk.slots - 11)
    pad_plan = _arms()[1]
    pad_got = run_via_plan(Planner(pad_db, shards=4 if not quick else 2), pad_plan)
    _check_same(pad_got, _oracle(pad_plan, pad_data), "uneven padding")

    speedups = {r["shards"]: r["speedup"] for r in strong
                if r["query"] == "sf1_grouped"}
    if quick:
        assert speedups[2] >= 1.0, f"smoke: no speedup at 2 shards: {speedups}"
    else:
        assert speedups[4] > 1.5, f"acceptance: {speedups[4]}x at 4 shards"

    payload = {
        "profile": {"n": bk.slots, "t": bk.t, "k": bk.profile.k},
        "rows": nrows, "quick": quick, "costs": costs,
        "strong_scaling": strong, "weak_scaling": weak,
        "speedups_grouped": speedups,
    }
    save_json("sharded_scan.json", payload)

    out = table(strong, f"strong scaling, {nrows} rows "
                        f"({db.tables['lineitem'].nblocks} blocks)")
    out += table(weak, "weak scaling (fixed blocks per shard)")
    out += (f"modeled speedup at {max(shard_counts)} shards: "
            f"{fmt_s(strong[0]['modeled_s'])} -> "
            f"{fmt_s(strong[len(shard_counts) - 1]['modeled_s'])}\n")

    if limb_shards is not None and limb_shards > 1:
        limb_rows = _limb_sweep(db, data, costs, limb_shards, quick)
        top = limb_rows[-1]
        if quick:
            assert top["speedup"] >= 1.0, \
                f"smoke: limb axis slowdown: {limb_rows}"
        else:
            assert top["speedup"] > 1.0, \
                f"acceptance: no limb-axis speedup: {limb_rows}"
        save_json("limb_sharding.json", {
            "profile": {"n": bk.slots, "t": bk.t, "k": bk.profile.k},
            "rows": nrows, "quick": quick,
            "gather_byte_s": costs["gather_byte"],
            "sweep": limb_rows,
        })
        out += table(limb_rows, "limb sharding (model axis, filtered arm)")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="8-block table, shards (1, 2): CI smoke mode")
    ap.add_argument("--limb-shards", type=int, default=None, metavar="M",
                    help="also sweep the model (RNS limb) axis up to M "
                         "and emit results/limb_sharding.json")
    a = ap.parse_args()
    print(main(quick=a.smoke, limb_shards=a.limb_shards))
