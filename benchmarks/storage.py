"""Fig. 7: storage expansion — NSHEDB's packed word-level ciphertexts vs
raw data and vs the ~8000x bit-level systems."""
from __future__ import annotations

from repro.core.noise import paper_profile
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.baseline import storage_report

from .common import save_json, table


def main(quick: bool = False) -> str:
    prof = paper_profile()
    rows = []
    for nrows in (4096, 8192, 16384, 32768):
        r = storage_report(prof, nrows, ncols=14, raw_bits=16)
        rows.append({
            "rows": nrows,
            "raw_MB": round(r["raw_bytes"] / 2**20, 2),
            "nshedb_MB": round(r["nshedb_bytes"] / 2**20, 1),
            "bitlevel_MB": round(r["bitlevel_bytes"] / 2**20, 0),
            "expansion_x_16bit": round(r["nshedb_expansion"], 1),
            "expansion_x_64bit": round(prof.expansion_ratio(64), 1),  # paper's ~28x base
            "reduction_vs_bitlevel_x": round(r["reduction_vs_bitlevel"], 1),
        })
    # whole-database view (all eight tables at bench scale)
    bk = MockBackend()
    db = tpch.load(bk, tpch.Scale.tiny() if quick else tpch.Scale.small())
    rows.append({
        "rows": "all 8 tables",
        "raw_MB": round(db.raw_bytes() / 2**20, 3),
        "nshedb_MB": round(db.storage_bytes() / 2**20, 1),
        "bitlevel_MB": round(db.raw_bytes() * 8000 / 2**20, 0),
        "expansion_x": round(db.storage_bytes() / db.raw_bytes(), 1),
        "reduction_vs_bitlevel_x": round(
            db.raw_bytes() * 8000 / db.storage_bytes(), 1),
    })
    save_json("fig7_storage.json", rows)
    return table(rows, "Fig. 7 — storage footprint (16-bit values)")


if __name__ == "__main__":
    print(main())
