"""Static plan verification cost: wall-clock of `Planner.verify` per
TPC-H builder and regime, against the execution time it fronts.

The verifier (engine/verify.py, DESIGN §10) re-executes the compiled
DAG over abstract noise states — scalar model arithmetic instead of
32768-slot ciphertext ops — so admission should cost milliseconds per
query while the guarded execution costs seconds.  This benchmark pins
that ratio and the per-query verdicts down in results/static_verify.json
so a verifier-cost regression (or a shipped plan going red) shows up in
the smoke lane.
"""
from __future__ import annotations

import time

from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.executor import Executor
from repro.engine.planner import Planner

from .common import save_json, table


def main(quick: bool = False) -> str:
    bk = MockBackend()
    db = tpch.load(bk, tpch.Scale.tiny())
    names = list(Q.PLAN_EXECUTABLE)
    if quick:
        names = ["Q6", "Q19"]           # shallowest + deepest shipped DAG
    rows = []
    for qn in names:
        for optimized in (True, False):
            pl = Planner(db, optimized=optimized, verify=False)
            cq = Executor(pl).compile(Q.QUERIES[qn][0]())
            t0 = time.time()
            rep = pl.verify(cq.plan)
            verify_s = time.time() - t0
            t0 = time.time()
            Executor(pl).run(Q.QUERIES[qn][0]())
            exec_s = time.time() - t0
            rows.append({
                "query": qn,
                "regime": "optimized" if optimized else "unoptimized",
                "verdict": "ok" if rep.ok else "FAIL",
                "errors": len(rep.errors),
                "warnings": len(rep.warnings),
                "decrypts": len(rep.decrypts),
                "verify_ms": round(verify_s * 1e3, 1),
                "exec_s": round(exec_s, 2),
                "overhead_pct": round(100.0 * verify_s / max(exec_s, 1e-9), 2),
            })
    worst = max(r["overhead_pct"] for r in rows)
    summary = {
        "all_ok": all(r["verdict"] == "ok" for r in rows),
        "worst_overhead_pct": worst,
        "total_verify_ms": round(sum(r["verify_ms"] for r in rows), 1),
    }
    save_json("static_verify.json", {"rows": rows, "summary": summary})
    out = table(rows, "Static plan verification vs execution (tiny scale)")
    return out + (f"all plans verify clean; worst admission overhead "
                  f"{worst:.2f}% of execution\n")


if __name__ == "__main__":
    print(main())
