"""Shared benchmark plumbing: cost calibration (measured on our JAX BFV,
extrapolated to paper parameters) and result formatting."""
from __future__ import annotations

import functools
import json
import os

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


# Keys results/op_costs.json may carry on top of the measured OpCosts
# fields; anything else is a typo or a schema drift and must fail loudly
# rather than silently mis-price every ledger built on top of it.
_EXTRA_COST_KEYS = ("gather_byte",)


@functools.lru_cache(maxsize=None)
def _calibration(quick: bool) -> tuple:
    """Load (or measure) the calibration point: returns
    (OpCosts, extras) where extras holds the optional overrides
    (`gather_byte`) the JSON file may carry alongside the measured
    fields.  Unknown or missing keys raise ValueError naming them —
    a stale or hand-edited results/op_costs.json must never surface
    as a cryptic TypeError (or worse, a silently wrong ledger)."""
    import dataclasses

    from repro.core.params import make_params
    from repro.engine.baseline import OpCosts, measure_costs

    cache = os.path.join(RESULTS, "op_costs.json")
    if os.path.exists(cache):
        with open(cache) as f:
            d = json.load(f)
        extras = {k: d.pop(k) for k in _EXTRA_COST_KEYS if k in d}
        fields = {f.name for f in dataclasses.fields(OpCosts)}
        required = {f.name for f in dataclasses.fields(OpCosts)
                    if f.default is dataclasses.MISSING}
        unknown, missing = sorted(set(d) - fields), sorted(required - set(d))
        if unknown or missing:
            raise ValueError(
                f"{cache}: bad calibration schema — "
                f"unknown keys {unknown}, missing keys {missing}; "
                f"delete the file to re-measure")
        return OpCosts(**d), extras
    params = make_params(n=1024 if quick else 4096, t=65537, k=8)
    measured = measure_costs(params, reps=2)
    os.makedirs(RESULTS, exist_ok=True)
    with open(cache, "w") as f:
        json.dump(measured.__dict__, f)
    return measured, {}


@functools.lru_cache(maxsize=None)
def paper_costs(quick: bool = False):
    """Per-op seconds at the paper's (n=32768, k=30).

    Measured at (n=4096, k=8) on the real RNS-BFV backend, scaled with
    the analytic complexity model (see engine/baseline.py).  ~30 s once
    per process; cached to disk afterwards.
    """
    from repro.engine.baseline import extrapolate_costs

    measured, _ = _calibration(quick)
    return extrapolate_costs(measured, 32768, 30)


def op_costs(quick: bool = False) -> dict:
    """Per-op cost dict every benchmark prices ledgers with.

    One loader for all of benchmarks/: the calibrated paper-parameter
    costs from results/op_costs.json (via paper_costs) plus the
    interconnect gather price the 2-D limb-sharded ledger consults —
    the JSON file may override `gather_byte`, otherwise the engine
    default applies.
    """
    from repro.engine.sharded import GATHER_BYTE_SECONDS

    d = paper_costs(quick).as_dict()
    _, extras = _calibration(quick)
    d["gather_byte"] = extras.get("gather_byte", GATHER_BYTE_SECONDS)
    return d


SEAL_EQ_MS_PER_SLOT = 0.09   # paper Table 4: NSHEDB equality on SEAL


def seal_norm_factor(quick: bool = False) -> float:
    """Our JAX BFV runs single-core; the paper's SEAL runs 16-core AVX.
    Anchoring our EQ (identical circuit: 16 squarings) to the paper's
    measured EQ gives a per-op normalization; every OTHER op's normalized
    time is then a structural prediction the paper's Table 4 must match
    (and does, within ~15% — see results/table4_primitive_ops.json)."""
    from repro.core import compare as cmp
    from repro.engine.backend import MockBackend
    import numpy as np
    bk = MockBackend()
    x = bk.encrypt(np.arange(8))
    bk.stats.reset()
    cmp.eq_scalar(bk, x, 3)
    ours_s = bk.stats.cost_seconds(paper_costs(quick).as_dict())
    ours_ms_slot = ours_s / 32768 * 1000
    return SEAL_EQ_MS_PER_SLOT / ours_ms_slot


def table(rows: list[dict], title: str) -> str:
    if not rows:
        return f"== {title} ==\n(no rows)\n"
    cols = list(rows[0])
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    out = [f"== {title} =="]
    out.append(" | ".join(str(c).ljust(widths[c]) for c in cols))
    out.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        out.append(" | ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(out) + "\n"


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def fmt_s(x: float) -> str:
    if x >= 100:
        return f"{x:,.0f}"
    if x >= 1:
        return f"{x:.1f}"
    return f"{x:.3f}"
