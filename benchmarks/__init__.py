"""Benchmark harness — one module per paper table/figure:

  primitive_ops    Table 4   per-op latencies vs HE3DB/ArcEDB
  tpch_queries     Fig. 6    nine queries, opt vs unopt vs baselines
  q6_breakdown     Table 5   Q6 phase breakdown (boot/filter/agg)
  packing_scaling  Table 6   runtime vs rows within the packing limit
  storage          Fig. 7    storage expansion vs bit-level systems
  depth_model      Table 3   per-operator multiplicative depth
  roofline         —         compute/memory/collective terms per dry-run cell

`python -m benchmarks.run` executes all of them.
"""
