"""Fused cross-mask launches vs the per-predicate legacy schedule.

The compiled operator DAG (engine/physical.py + engine/executor.py)
evaluates every distinct comparison circuit of a query in ONE stacked
launch per circuit shape — all EQ square chains together, all LT
interpolants together, across columns and tables — and CSE-deduplicates
repeated (column, op, value) subgraphs.  This benchmark measures that
against the pre-DAG schedule (one launch per predicate, no sharing) on
the two queries the refactor targets:

  Q1   9 group/WHERE EQ circuits collapse to 5 (CSE) in 1 fused launch
  Q19  ~30 per-branch part/lineitem circuit launches collapse to one EQ
       and one LT launch; the shared `p_size >= 1` atoms are CSE hits

Launch count = primitive *calls* into the backend (OpStats.launches, the
quantity batching removes); ct_mul / max_depth are charged per block and
must NOT improve from fusion alone — equal op-depth accounting — only
from CSE.  Wall-clock is the mock backend at the paper profile.
"""
from __future__ import annotations

import time

from repro.engine import ops
from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.executor import run_via_plan
from repro.engine.planner import Planner

from .common import save_json, table

QUERIES = ["Q1", "Q19"]


def _measure(bk, fn):
    bk.stats.reset()
    bk.op_log.clear()
    t0 = time.perf_counter()
    fn()
    wall = time.perf_counter() - t0
    s = bk.stats.clone()
    return s, bk.op_log["eq"] + bk.op_log["cmp"], wall


def _planner(db, fused: bool) -> Planner:
    pl = Planner(db, optimized=True)
    pl.fuse_masks = fused
    pl.share_masks = fused
    return pl


def _mask_phase(pl: Planner, db, qn: str) -> None:
    """Predicate-mask evaluation only (no aggregation): Q1's WHERE + the
    3x2 group-pair EQ grid as the legacy nested loop walks it (the inner
    dictionary re-evaluated per outer value — CSE's target), and Q19's
    full three-branch WHERE tree including the part-side translates."""
    bk = pl.bk
    li = db.tables["lineitem"]
    if qn == "Q1":
        plan = Q.plan_q1()
        where = pl.where_mask(li, plan.where)
        rf = li.schema.col("l_returnflag").dictionary
        ls = li.schema.col("l_linestatus").dictionary
        for _, rv in sorted(rf.items()):
            rfm = dict(pl.group_masks(li, "l_returnflag", [rv]))[rv]
            for _, lv in sorted(ls.items()):
                lsm = dict(pl.group_masks(li, "l_linestatus", [lv]))[lv]
                ops.and_masks(bk, [rfm, lsm, where])
    else:
        pl.where_mask(li, Q.plan_q19().where)


def bfv_mask_phase(quick: bool = False) -> list[dict]:
    """The same fused-vs-separate schedule on REAL ciphertexts (micro
    t=257 domain): here per-launch dispatch overhead is genuine, so the
    launch reduction turns into wall-clock."""
    import numpy as np

    from repro.core.params import make_params
    from repro.engine.backend import BFVBackend
    from repro.engine.plan import And, Pred
    from repro.engine.schema import ColumnSpec, TableSchema
    from repro.engine.storage import Database

    bk = BFVBackend(make_params(n=128, t=257, k=12), seed=5)
    db = Database(bk)
    rng = np.random.default_rng(5)
    n = 128 if quick else 512                     # 1 / 4 ciphertext blocks
    db.load_table(TableSchema("sales", [
        ColumnSpec("day", "int"), ColumnSpec("price", "int"),
        ColumnSpec("qty", "int")]), {
        "day": rng.integers(1, 101, n), "price": rng.integers(1, 101, n),
        "qty": rng.integers(1, 11, n)}, n)
    tbl = db.tables["sales"]
    expr = And((Pred("day", "<", 50), Pred("qty", ">=", 3),
                Pred("price", "between", (20, 80)), Pred("day", ">", 5),
                Pred("qty", "=", 7)))
    rows = []
    results = {}
    for arm, fused in (("separate", False), ("fused", True)):
        times = []
        for rep in range(3):                      # rep 0 warms the jit cache
            pl = _planner(db, fused)
            bk.stats.reset()
            t0 = time.perf_counter()
            mask = pl.where_mask(tbl, expr)
            times.append(time.perf_counter() - t0)
            results[arm] = bk.decrypt(mask[0])
        rows.append({
            "backend": f"bfv(n=128,t=257) x{tbl.nblocks} blocks",
            "arm": arm,
            "launches": bk.stats.launches,
            "ct_mul": bk.stats.mul,
            "wall_ms": round(min(times[1:]) * 1e3, 1),
        })
    assert (results["separate"] == results["fused"]).all(), "mask drift"
    save_json("mask_fusion_bfv.json", rows)
    return rows


def run(scale=None, quick: bool = False) -> list[dict]:
    scale = scale or (tpch.Scale.tiny() if quick else tpch.Scale.small())
    bk = MockBackend()
    db = tpch.load(bk, scale)
    rows = []
    for qn in QUERIES:
        plan_f, run_f, oracle_f = Q.QUERIES[qn]
        # Mask phase in isolation: separate (per-predicate launches, no
        # sharing) vs fused (cross-mask batches + CSE).
        msep, msep_circ, msep_wall = _measure(
            bk, lambda: _mask_phase(_planner(db, False), db, qn))
        mfus, mfus_circ, mfus_wall = _measure(
            bk, lambda: _mask_phase(_planner(db, True), db, qn))
        # Whole query end to end: legacy body unfused vs compiled DAG.
        sep, _, sep_wall = _measure(bk, lambda: run_f(_planner(db, False)))
        got = {}
        fused, _, fused_wall = _measure(
            bk, lambda: got.update(run_via_plan(_planner(db, True), plan_f())))
        assert got == oracle_f(db), f"{qn}: fused result != oracle"
        assert fused.max_depth == sep.max_depth, "op-depth accounting drifted"
        rows.append({
            "query": qn,
            "mask_launches_sep": msep.launches,
            "mask_launches_fused": mfus.launches,
            "mask_launch_ratio": round(msep.launches / mfus.launches, 2),
            "circuits_sep": msep_circ,
            "circuits_fused": mfus_circ,
            "mask_wall_sep_s": round(msep_wall, 3),
            "mask_wall_fused_s": round(mfus_wall, 3),
            "query_launches_sep": sep.launches,
            "query_launches_fused": fused.launches,
            "query_ct_mul_sep": sep.mul,
            "query_ct_mul_fused": fused.mul,
            "max_depth": fused.max_depth,
            "query_wall_sep_s": round(sep_wall, 3),
            "query_wall_fused_s": round(fused_wall, 3),
        })
    save_json("mask_fusion.json", rows)
    return rows


def main(quick: bool = False) -> str:
    out = table(run(quick=quick),
                "Cross-mask fusion + CSE — compiled DAG vs per-predicate "
                "launches (mock backend, optimized regime)")
    out += "\n" + table(bfv_mask_phase(quick=quick),
                        "Fused mask evaluation on real BFV ciphertexts "
                        "(5-predicate WHERE, launch overhead is real)")
    return out


if __name__ == "__main__":
    print(main())
