"""Fig. 6: TPC-H query times — NSHEDB with/without noise optimization
(our engine, op-counted and priced with measured per-op costs) vs the
bit-level baselines (paper-reported anchors where quoted; Table-4 op
model elsewhere)."""
from __future__ import annotations

import time

from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.baseline import (PAPER_QUERY_SECONDS, baseline_seconds,
                                   nshedb_seconds)
from repro.engine.planner import Planner

from .common import fmt_s, paper_costs, save_json, seal_norm_factor, table

QUERIES = ["Q1", "Q4", "Q5", "Q6", "Q8", "Q12", "Q14", "Q17", "Q19"]


def run(scale=None, queries=None, quick: bool = False):
    scale = scale or (tpch.Scale.tiny() if quick else tpch.Scale.small())
    queries = queries or QUERIES
    costs = paper_costs(quick)
    norm = seal_norm_factor(quick)   # anchor per-op cost to the paper's SEAL EQ
    bk = MockBackend()
    db = tpch.load(bk, scale)
    rows = []
    for qn in queries:
        _, run_f, oracle_f = Q.QUERIES[qn]
        rec = {"query": qn}
        for optimized in (True, False):
            pl = Planner(db, optimized=optimized)
            bk.stats.reset()
            bk.op_log.clear()
            t0 = time.time()
            got = run_f(pl)
            ok = got == oracle_f(db)
            tag = "opt" if optimized else "noopt"
            sec = nshedb_seconds(bk.stats, costs)
            # normalize HE-op time to the SEAL anchor; refreshes stay at
            # the literature's 44 s/ciphertext (they are not our ops).
            sec_normed = (sec - bk.stats.refresh * costs.refresh) * norm \
                + bk.stats.refresh * costs.refresh
            rec[f"nshedb_{tag}_s"] = fmt_s(sec_normed)
            rec[f"refresh_{tag}"] = bk.stats.refresh
            if optimized:
                he3 = baseline_seconds("he3db", bk.op_log, 32768)
                rec["he3db_model_s"] = fmt_s(he3)
                rec["arcedb_model_s"] = fmt_s(
                    baseline_seconds("arcedb", bk.op_log, 32768))
                rec["speedup_he3db"] = round(he3 / max(sec_normed, 1e-9))
            rec["match" if optimized else "match_noopt"] = ok
        anchors = PAPER_QUERY_SECONDS.get(qn, {})
        if anchors:
            rec["paper_he3db_s"] = anchors.get("he3db", "")
            rec["paper_nshedb_s"] = anchors.get("nshedb", anchors.get("nshedb_noopt", ""))
        rows.append(rec)
    save_json("fig6_tpch_queries.json", rows)
    return table(rows, "Fig. 6 — TPC-H queries (SEAL-normed seconds at paper "
                       "params, 32K rows; refreshes priced at 44 s)")


def main(quick: bool = False) -> str:
    return run(quick=quick)


if __name__ == "__main__":
    print(main())
