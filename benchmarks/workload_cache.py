"""Persistent WorkloadCache across a query mix: repeated-query and
drill-down suites (engine/workload.py).

In HE engines the comparison circuits dominate query cost, so reuse
across a dashboard's query mix is the cheapest speedup available — the
encrypted analogue of PartitionCache's cached partition-key conditions.
Two suites, both on the mock backend at the paper parameter profile:

  repeated    the executable TPC-H mix (Q1, Q6, Q12, Q19) scheduled
              twice through `run_workload`: the cold pass batch-fuses
              every distinct circuit of all four queries into one
              stacked launch per shape; the warm pass serves every atom
              and per-key join bank from the cache (noise-checked) and
              re-runs none.
  drilldown   a progressively narrowed Q6-style predicate stack — each
              step adds one predicate and reuses every mask the previous
              steps derived, so the hit rate climbs step over step.

Emits results/workload_cache.json; CI's smoke lane asserts the summary
reports a nonzero cross-query hit rate.
"""
from __future__ import annotations

import time

from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.plan import Agg, And, Factor, Pred, QueryPlan
from repro.engine.planner import Planner
from repro.engine.workload import WorkloadCache, run_workload

from .common import save_json, table

MIX = list(Q.PLAN_EXECUTABLE)             # Q1, Q6, Q12, Q19


def _drill_plans() -> list[QueryPlan]:
    """Dashboard drill-down: each step narrows the previous WHERE."""
    D = Q.D
    year = (Pred("l_shipdate", ">=", D("1994-01-01")),
            Pred("l_shipdate", "<", D("1995-01-01")))
    disc = (Pred("l_discount", "between", (0.05, 0.07)),)
    qty = (Pred("l_quantity", "<", 24),)
    mode = (Pred("l_shipmode", "in", ["MAIL", "SHIP"]),)
    steps = [
        ("d1_year", year),
        ("d2_discount", year + disc),
        ("d3_quantity", year + disc + qty),
        ("d4_shipmode", year + disc + qty + mode),
    ]
    return [QueryPlan(name=name, fact="lineitem", where=And(preds),
                      aggs=(Agg("sum", (Factor("l_extendedprice"),
                                        Factor("l_discount")), "revenue"),
                            Agg("count", (), "n")))
            for name, preds in steps]


def _pass_row(label: str, rep, wall: float) -> dict:
    return {
        "pass": label,
        "launches": rep.launches,
        "ct_mul": rep.muls,
        "refreshes": rep.refreshes,
        "hits": rep.cache.hits,
        "misses": rep.cache.misses,
        "hit_rate": round(rep.hit_rate, 3),
        "wall_s": round(wall, 3),
    }


def run(scale=None, quick: bool = False) -> dict:
    scale = scale or (tpch.Scale.tiny() if quick else tpch.Scale.small())
    bk = MockBackend()
    db = tpch.load(bk, scale)

    # -- repeated-query suite --------------------------------------------
    cache = WorkloadCache()
    pl = Planner(db, optimized=True, cache=cache)
    plans = [Q.QUERIES[qn][0]() for qn in MIX]
    repeated = []
    passes = {}
    for label in ("cold", "warm"):
        t0 = time.perf_counter()
        rep = run_workload(pl, plans)
        passes[label] = rep
        repeated.append(_pass_row(label, rep, time.perf_counter() - t0))
    cold, warm = passes["cold"], passes["warm"]
    assert cold.results == warm.results, "warm pass decrypts must match cold"
    oracles = [Q.QUERIES[qn][2](db) for qn in MIX]
    assert cold.results == oracles, "workload results != plaintext oracle"
    assert warm.hit_rate > 0.5, f"warm hit rate {warm.hit_rate} <= 0.5"
    assert warm.launches < cold.launches, "warm pass must launch fewer circuits"

    # -- drill-down suite ------------------------------------------------
    dcache = WorkloadCache()
    dpl = Planner(db, optimized=True, cache=dcache)
    drill = []
    for plan in _drill_plans():
        t0 = time.perf_counter()
        rep = run_workload(dpl, [plan])
        drill.append({
            "step": plan.name,
            "launches": rep.launches,
            "hits": rep.cache.hits,
            "misses": rep.cache.misses,
            "wall_s": round(time.perf_counter() - t0, 3),
        })
    assert drill[0]["hits"] == 0 and all(d["hits"] > 0 for d in drill[1:]), \
        "every narrowed step must reuse earlier masks"

    payload = {
        "repeated": repeated,
        "drilldown": drill,
        "summary": {
            "queries": MIX,
            "cross_query_hit_rate": round(warm.hit_rate, 3),
            "cold_launches": cold.launches,
            "warm_launches": warm.launches,
            "launch_ratio": round(cold.launches / warm.launches, 2),
            "warm_circuit_evals": warm.cache.misses,
            "fk_bank_hits_warm": warm.cache.fk_hits,
        },
    }
    save_json("workload_cache.json", payload)
    return payload


def main(quick: bool = False) -> str:
    payload = run(quick=quick)
    out = table(payload["repeated"],
                "Workload cache — cold vs warm pass over Q1+Q6+Q12+Q19 "
                "(mock backend, cross-query fused scheduling)")
    out += "\n" + table(payload["drilldown"],
                        "Drill-down suite — each step narrows the WHERE and "
                        "reuses cached masks")
    s = payload["summary"]
    out += (f"\ncross-query hit rate {s['cross_query_hit_rate']}, launches "
            f"{s['cold_launches']} -> {s['warm_launches']} "
            f"({s['launch_ratio']}x)")
    return out


if __name__ == "__main__":
    print(main())
