"""Table 6: Q6 runtime vs row count within the packing limit — NSHEDB is
flat (one ciphertext covers <= 32,768 rows; every op is whole-ciphertext)
while the bit-level baseline scales linearly with rows."""
from __future__ import annotations

from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.baseline import baseline_seconds, nshedb_seconds
from repro.engine.planner import Planner

from .common import fmt_s, paper_costs, save_json, seal_norm_factor, table


def main(quick: bool = False) -> str:
    costs = paper_costs(quick)
    norm = seal_norm_factor(quick)
    rows = []
    sizes = [512, 2048] if quick else [4096, 8192, 16384, 32768]
    for n in sizes:
        bk = MockBackend()
        scale = tpch.Scale(lineitem=n, orders=max(n // 4, 16),
                           customer=16, supplier=8, part=16, partsupp=16)
        db = tpch.load(bk, scale, tables=["lineitem"])
        pl = Planner(db, optimized=True)
        bk.stats.reset()
        bk.op_log.clear()
        Q.run_q6(pl)
        ours = nshedb_seconds(bk.stats, costs) * norm
        he3 = baseline_seconds("he3db", bk.op_log, n)
        rows.append({"rows": n, "nshedb_s": fmt_s(ours),
                     "he3db_model_s": fmt_s(he3),
                     "speedup": round(he3 / max(ours, 1e-9), 1),
                     "ciphertext_blocks": db.tables["lineitem"].nblocks})
    save_json("table6_packing_scaling.json", rows)
    return table(rows, "Table 6 — Q6 scaling within the packing limit")


if __name__ == "__main__":
    print(main())
