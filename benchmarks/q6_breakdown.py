"""Table 5: Q6 execution breakdown — bootstrapping / filter / conversion /
aggregation.  NSHEDB's column must show zero bootstrap and zero
transciphering; the filter (comparison circuits) dominates."""
from __future__ import annotations

from repro.engine import ops, tpch
from repro.engine.backend import MockBackend
from repro.engine.baseline import PAPER_QUERY_SECONDS, nshedb_seconds
from repro.engine.plan import Agg, And, Factor, Pred
from repro.engine.planner import Planner
from repro.engine.schema import date_to_int as D

from .common import fmt_s, paper_costs, save_json, seal_norm_factor, table


def main(quick: bool = False) -> str:
    costs = paper_costs(quick)
    bk = MockBackend()
    db = tpch.load(bk, tpch.Scale.tiny() if quick else tpch.Scale.small(),
                   tables=["lineitem"])
    pl = Planner(db, optimized=True)
    li = db.tables["lineitem"]

    # phase 1: filter (all comparison masks + combine)
    bk.stats.reset()
    mask = pl.where_mask(li, And((
        Pred("l_shipdate", ">=", D("1994-01-01")),
        Pred("l_shipdate", "<", D("1995-01-01")),
        Pred("l_discount", "between", (0.05, 0.07)),
        Pred("l_quantity", "<", 24))))
    filter_stats = bk.stats.clone()

    # phase 2: aggregation (mask multiply + rotate-reduce)
    bk.stats.reset()
    pl.aggregate(li, Agg("sum", (Factor("l_extendedprice"),
                                 Factor("l_discount")), "revenue"), mask)
    agg_stats = bk.stats.clone()

    norm = seal_norm_factor(quick)
    filt_s = nshedb_seconds(filter_stats, costs) * norm
    agg_s = nshedb_seconds(agg_stats, costs) * norm
    boot_s = (filter_stats.refresh + agg_stats.refresh) * costs.refresh
    total = filt_s + agg_s + boot_s
    rows = [
        {"system": "HE3DB (paper)", "boot_s": 11509, "filter_s": 251,
         "conv_s": 42, "agg_s": 0.01, "total_s": 11802},
        {"system": "ArcEDB (paper)", "boot_s": 2753, "filter_s": 430,
         "conv_s": 74, "agg_s": 0.21, "total_s": 3257},
        {"system": "NSHEDB (paper)", "boot_s": 0, "filter_s": 589,
         "conv_s": 0, "agg_s": 1.41, "total_s": 590},
        {"system": "NSHEDB (ours)", "boot_s": fmt_s(boot_s),
         "filter_s": fmt_s(filt_s), "conv_s": 0, "agg_s": fmt_s(agg_s),
         "total_s": fmt_s(total)},
    ]
    save_json("table5_q6_breakdown.json", rows)
    return table(rows, "Table 5 — Q6 execution breakdown (seconds, 32K rows)")


if __name__ == "__main__":
    print(main())
