"""Table 4: primitive database operations — NSHEDB per-op latency
(measured/extrapolated on our JAX BFV) vs the paper's HE3DB/ArcEDB
numbers, reported per slot at 32K rows like the paper.

Also measures the batched column path (one stacked jitted call for a
whole column of blocks) against the per-block Python loop on the real
RNS-BFV backend — the before/after of the batched evaluation layer —
for pointwise add, plaintext multiply, ct-ct multiply, and the raw
forward NTT."""
from __future__ import annotations

import time

import numpy as np

from repro.engine.backend import BFVBackend, MockBackend
from repro.engine.baseline import TABLE4_MS_PER_SLOT
from repro.core import compare as cmp

from .common import paper_costs, save_json, seal_norm_factor, table


def op_counts() -> dict[str, object]:
    """Run each primitive once on the mock backend; return its OpStats."""
    out = {}
    ops_to_run = {
        "count": lambda bk, x: bk.sum_slots(x),
        "sum": lambda bk, x: bk.sum_slots(bk.mul(x, x)),
        "eq": lambda bk, x: cmp.eq_scalar(bk, x, 7),
        "cmp": lambda bk, x: cmp.lt_scalar(bk, x, 7),
        "between": lambda bk, x: cmp.between_scalar(bk, x, 3, 9),
        "in": lambda bk, x: cmp.in_set(bk, x, [1, 2, 3]),
        "groupby": lambda bk, x: [cmp.eq_scalar(bk, x, v) for v in (1, 2, 3)],
    }
    for name, fn in ops_to_run.items():
        bk = MockBackend()
        x = bk.encrypt(np.arange(100))
        bk.stats.reset()
        fn(bk, x)
        out[name] = bk.stats.clone()
    return out


def batched_vs_looped(nblocks: int = 8, quick: bool = False) -> list[dict]:
    """Per-op wall clock: batched column call vs per-block loop.

    Real ciphertexts at the test parameter set (n=2048, k=5, or 256/3 in
    quick mode) — large enough that per-call dispatch overhead, the thing
    batching removes, is visible against real kernel work."""
    import jax
    from repro.core.params import make_params, test_params

    params = test_params() if quick else make_params(n=2048, t=65537, k=5)
    bk = BFVBackend(params, seed=0)
    ctx = bk.ctx
    rng = np.random.default_rng(0)
    xs = [bk.encrypt(rng.integers(0, params.t, params.n)) for _ in range(nblocks)]
    ys = [bk.encrypt(rng.integers(0, params.t, params.n)) for _ in range(nblocks)]
    sx, sy = ctx.stack_cts(xs), ctx.stack_cts(ys)
    m_poly = bk.enc.encode(rng.integers(0, params.t, params.n))
    poly_batch = sx.data[:, 0]                      # (nblocks, k, n) limbs

    def timed(fn, out_of):
        jax.block_until_ready(out_of(fn()))         # warmup / compile, drained
        reps = 3 if quick else 10
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn()
        jax.block_until_ready(out_of(r))
        return (time.perf_counter() - t0) / reps

    cases = {
        "add": (lambda: [ctx.add(a, b) for a, b in zip(xs, ys)],
                lambda: ctx.add(sx, sy)),
        "mul_plain": (lambda: [ctx.mul_plain(a, m_poly) for a in xs],
                      lambda: ctx.mul_plain(sx, m_poly)),
        "mul": (lambda: [ctx.mul(a, b, bk.keys.rlk) for a, b in zip(xs, ys)],
                lambda: ctx.mul(sx, sy, bk.keys.rlk)),
        "ntt_fwd": (lambda: [ctx._ntt_q(x.data[0]) for x in xs],
                    lambda: ctx._ntt_q(poly_batch)),
    }

    def leaves(r):
        if isinstance(r, list):
            return [getattr(x, "data", x) for x in r]
        return getattr(r, "data", r)

    rows = []
    for op, (looped, batched) in cases.items():
        t_loop = timed(looped, leaves)
        t_batch = timed(batched, leaves)
        rows.append({
            "op": op,
            "nblocks": nblocks,
            "looped_ms": round(t_loop * 1e3, 3),
            "batched_ms": round(t_batch * 1e3, 3),
            "speedup": round(t_loop / max(t_batch, 1e-9), 2),
        })
    save_json("batched_vs_looped.json", rows)
    return rows


def main(quick: bool = False) -> str:
    costs = paper_costs(quick)
    norm = seal_norm_factor(quick)
    counts = op_counts()
    slots = 32768
    rows = []
    for op, stats in counts.items():
        ours_s = stats.cost_seconds(costs.as_dict())
        ours_ms_slot = ours_s / slots * 1000
        div = 3 if op == "groupby" else 1   # per-distinct-value, like Table 4
        ours = ours_ms_slot / div
        normed = ours * norm                 # anchored to the paper's EQ
        paper = TABLE4_MS_PER_SLOT["nshedb_paper"].get(op)
        row = {
            "op": op,
            "ct_muls": stats.mul,
            "rotations": stats.rotate,
            "ours_jax1core_ms": round(ours, 3),
            "ours_seal_normed_ms": round(normed, 3),
            "nshedb_paper_ms": paper,
            "he3db_ms": TABLE4_MS_PER_SLOT["he3db"].get(op, ""),
            "arcedb_ms": TABLE4_MS_PER_SLOT["arcedb"].get(op, ""),
        }
        if paper:
            row["struct_match"] = round(normed / paper, 2)   # ~1.0 = faithful
        he3 = TABLE4_MS_PER_SLOT["he3db"].get(op)
        if he3:
            row["speedup_vs_he3db"] = round(he3 / max(normed, 1e-9), 1)
        rows.append(row)
    save_json("table4_primitive_ops.json", rows)
    out = table(rows, "Table 4 — primitive operations (ms per slot, 32K rows; "
                      "normed = anchored to the paper's EQ measurement)")
    out += "\n" + table(batched_vs_looped(quick=quick),
                        "Batched column path vs per-block loop (real BFV, "
                        "wall-clock per column op)")
    return out


if __name__ == "__main__":
    print(main())
