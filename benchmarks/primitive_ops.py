"""Table 4: primitive database operations — NSHEDB per-op latency
(measured/extrapolated on our JAX BFV) vs the paper's HE3DB/ArcEDB
numbers, reported per slot at 32K rows like the paper."""
from __future__ import annotations

import numpy as np

from repro.engine.backend import MockBackend
from repro.engine.baseline import TABLE4_MS_PER_SLOT
from repro.core import compare as cmp

from .common import paper_costs, save_json, seal_norm_factor, table


def op_counts() -> dict[str, object]:
    """Run each primitive once on the mock backend; return its OpStats."""
    out = {}
    ops_to_run = {
        "count": lambda bk, x: bk.sum_slots(x),
        "sum": lambda bk, x: bk.sum_slots(bk.mul(x, x)),
        "eq": lambda bk, x: cmp.eq_scalar(bk, x, 7),
        "cmp": lambda bk, x: cmp.lt_scalar(bk, x, 7),
        "between": lambda bk, x: cmp.between_scalar(bk, x, 3, 9),
        "in": lambda bk, x: cmp.in_set(bk, x, [1, 2, 3]),
        "groupby": lambda bk, x: [cmp.eq_scalar(bk, x, v) for v in (1, 2, 3)],
    }
    for name, fn in ops_to_run.items():
        bk = MockBackend()
        x = bk.encrypt(np.arange(100))
        bk.stats.reset()
        fn(bk, x)
        out[name] = bk.stats.clone()
    return out


def main(quick: bool = False) -> str:
    costs = paper_costs(quick)
    norm = seal_norm_factor(quick)
    counts = op_counts()
    slots = 32768
    rows = []
    for op, stats in counts.items():
        ours_s = stats.cost_seconds(costs.as_dict())
        ours_ms_slot = ours_s / slots * 1000
        div = 3 if op == "groupby" else 1   # per-distinct-value, like Table 4
        ours = ours_ms_slot / div
        normed = ours * norm                 # anchored to the paper's EQ
        paper = TABLE4_MS_PER_SLOT["nshedb_paper"].get(op)
        row = {
            "op": op,
            "ct_muls": stats.mul,
            "rotations": stats.rotate,
            "ours_jax1core_ms": round(ours, 3),
            "ours_seal_normed_ms": round(normed, 3),
            "nshedb_paper_ms": paper,
            "he3db_ms": TABLE4_MS_PER_SLOT["he3db"].get(op, ""),
            "arcedb_ms": TABLE4_MS_PER_SLOT["arcedb"].get(op, ""),
        }
        if paper:
            row["struct_match"] = round(normed / paper, 2)   # ~1.0 = faithful
        he3 = TABLE4_MS_PER_SLOT["he3db"].get(op)
        if he3:
            row["speedup_vs_he3db"] = round(he3 / max(normed, 1e-9), 1)
        rows.append(row)
    save_json("table4_primitive_ops.json", rows)
    return table(rows, "Table 4 — primitive operations (ms per slot, 32K rows; "
                       "normed = anchored to the paper's EQ measurement)")


if __name__ == "__main__":
    print(main())
