"""Run every benchmark (one per paper table/figure) + the roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny scales / fewer sizes (CI mode)")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()

    from . import (depth_model, fault_recovery, mask_fusion, packing_scaling,
                   primitive_ops, q6_breakdown, roofline, sharded_scan,
                   static_verify, storage, tpch_queries, workload_cache)
    mods = {
        "depth_model": depth_model,
        "static_verify": static_verify,
        "primitive_ops": primitive_ops,
        "storage": storage,
        "q6_breakdown": q6_breakdown,
        "packing_scaling": packing_scaling,
        "mask_fusion": mask_fusion,
        "workload_cache": workload_cache,
        "sharded_scan": sharded_scan,
        "tpch_queries": tpch_queries,
        "fault_recovery": fault_recovery,
        "roofline": roofline,
    }
    if args.only:
        mods = {k: v for k, v in mods.items() if k in args.only.split(",")}
    failed = []
    for name, mod in mods.items():
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        try:
            print(mod.main(quick=args.quick))
        except Exception:
            traceback.print_exc()
            print(f"[{name}] FAILED")
            failed.append(name)
        print(f"[{name}] {time.time() - t0:.1f}s", flush=True)
    if failed:
        raise SystemExit(f"benchmark modules failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
