"""Recovery overhead under injected faults (runtime/faults.py,
DESIGN §9).

For each query of the executable TPC-H mix (Q1, Q6, Q12, Q19) we run a
fault-free baseline and then one run per fault class — transient noise
under-prediction, device loss mid-scan, a 10x straggler, and a poisoned
mask cache — and compare circuit-launch counts and recovery events.
Launches are the overhead metric because they are deterministic: the
stage checkpoints mean a retry replays completed stages from their
materialized masks instead of recomputing them, so a recovered run
should relaunch only the failed tail.  The headline contract asserted
here (and in CI's tests-chaos lane via --smoke): every recovered run
decrypts byte-identical to its baseline, and worst-case launch overhead
stays under 2x fault-free.

Emits results/fault_recovery.json.
"""
from __future__ import annotations

from repro.core.noise import NoiseProfile
from repro.engine import queries as Q
from repro.engine import tpch
from repro.engine.backend import MockBackend
from repro.engine.executor import Executor
from repro.engine.planner import Planner
from repro.engine.workload import WorkloadCache
from repro.runtime import faults
from repro.runtime.elastic import StragglerDetector

from .common import op_costs, save_json, table

MIX = list(Q.PLAN_EXECUTABLE)             # Q1, Q6, Q12, Q19
MULTIBLOCK = NoiseProfile(n=64, t=65537, k=30)
# Calibrated per-op seconds: straggler thresholds are relative to the
# fleet median, so any consistent cost scale gives the same exclusions.
COSTS = op_costs(quick=True)
MAX_OVERHEAD = 2.0


def _exec(db, qname, fault_plan=None, shards=2, cache=None, det=None):
    pl = Planner(db, optimized=True, shards=shards, cache=cache)
    if det is not None:
        pl.attach_straggler_detector(det, COSTS)
    ex = Executor(pl)
    qplan = Q.QUERIES[qname][0]()
    if fault_plan is None:
        out = ex.run(qplan)
    else:
        with faults.inject(fault_plan):
            out = ex.run(qplan)
    return out, ex.report


def _scenarios(db, qname):
    """(label, runner) pairs; each runner returns (result, report)."""
    def overflow():
        return _exec(db, qname, faults.FaultPlan(underpredict_bits=500.0,
                                                 underpredict_count=3))

    def device_loss():
        return _exec(db, qname, faults.FaultPlan(device_loss_stage="any",
                                                 device_loss_worker=1))

    def straggler():
        det = StragglerDetector(threshold=2.0, patience=1, timeout_s=1e9)
        fp = faults.FaultPlan(straggler_slowdown={3: 10.0})
        pl = Planner(db, optimized=True, shards=4)
        pl.attach_straggler_detector(det, COSTS)
        with faults.inject(fp):
            ex = Executor(pl)
            ex.run(Q.QUERIES[qname][0]())         # round 1: strike + reshard
            ex2 = Executor(pl)
            out = ex2.run(Q.QUERIES[qname][0]())  # round 2: on survivors
        return out, ex2.report

    def cache_poison():
        # One corrupted entry (a realistic bit-flip event; wholesale
        # corruption is a correctness case in tests/test_chaos.py, and
        # its unfused per-atom re-derivation costs more than a cold run).
        cache = WorkloadCache()
        pl = Planner(db, optimized=True, cache=cache)
        Executor(pl).run(Q.QUERIES[qname][0]())   # populate
        faults.poison_cache(cache, db.bk, entries=1)
        ex = Executor(pl)
        out = ex.run(Q.QUERIES[qname][0]())
        assert cache.stats.poison_drops > 0
        return out, ex.report

    return [("overflow", overflow), ("device-loss", device_loss),
            ("straggler", straggler), ("cache-poison", cache_poison)]


def run(quick: bool = False) -> dict:
    bk = MockBackend(MULTIBLOCK)
    db = tpch.load(bk, tpch.Scale.tiny(), seed=7)
    queries = ["Q6"] if quick else MIX

    rows, worst = [], 0.0
    for qname in queries:
        base_out, base_rep = _exec(db, qname)
        for fault, runner in _scenarios(db, qname):
            out, rep = runner()
            assert out == base_out, \
                f"{fault}/{qname}: recovered decrypt differs from baseline"
            base_launch = max(base_rep.launches, 1)
            overhead = rep.launches / base_launch
            worst = max(worst, overhead)
            rows.append({
                "query": qname,
                "fault": fault,
                "base_launches": base_rep.launches,
                "launches": rep.launches,
                "overhead": round(overhead, 3),
                "recoveries": len(rep.recoveries),
                "refreshes": rep.refreshes,
            })

    payload = {
        "profile": {"n": MULTIBLOCK.n, "t": MULTIBLOCK.t, "k": MULTIBLOCK.k},
        "queries": queries,
        "rows": rows,
        "summary": {
            "worst_launch_overhead": round(worst, 3),
            "budget": MAX_OVERHEAD,
            "all_identical": True,        # asserted above per scenario
            "total_recoveries": sum(r["recoveries"] for r in rows),
        },
    }
    save_json("fault_recovery.json", payload)
    assert worst < MAX_OVERHEAD, \
        f"worst recovery launch overhead {worst:.2f}x >= {MAX_OVERHEAD}x budget"
    return payload


def main(quick: bool = False) -> str:
    payload = run(quick=quick)
    s = payload["summary"]
    out = table(payload["rows"],
                "Fault recovery — launch overhead vs fault-free baseline "
                "(mock backend, paper noise profile, stage checkpoints)")
    out += (f"\nworst launch overhead {s['worst_launch_overhead']}x "
            f"(budget {s['budget']}x), {s['total_recoveries']} recoveries, "
            f"all decrypts identical to baseline")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-query run + overhead assertion (CI mode)")
    print(main(quick=ap.parse_args().smoke))
