"""Table 3: multiplicative depth per operator — analytic formula vs the
depth actually measured on the mock backend at paper parameters."""
from __future__ import annotations

import math

import numpy as np

from repro.core import compare as cmp
from repro.engine.backend import MockBackend

from .common import save_json, table


def _measure(fn) -> int:
    bk = MockBackend()
    x = bk.encrypt(np.arange(64))
    y = bk.encrypt(np.arange(64)[::-1])
    bk.stats.reset()
    fn(bk, x, y)
    return bk.stats.max_depth


def main(quick: bool = False) -> str:
    t = 65537
    n = 32768
    lg = math.ceil(math.log2(t - 1))
    rows = [
        {"operator": "equality", "formula": "ceil(log2(p-1))", "predicted": lg,
         "measured": _measure(lambda bk, x, y: cmp.eq_ct(bk, x, y))},
        {"operator": "comparison (<)", "formula": "ceil(log2(p-1)) + 1",
         "predicted": lg + 1,
         "measured": _measure(lambda bk, x, y: cmp.lt_ct(bk, x, y))},
        {"operator": "between", "formula": "ceil(log2(p-1)) + 2",
         "predicted": lg + 2,
         "measured": _measure(lambda bk, x, y: cmp.between_scalar(bk, x, 3, 9))},
        {"operator": "in (k=4)", "formula": "ceil(log2(p-1)) + log(k)/p",
         "predicted": lg,
         "measured": _measure(lambda bk, x, y: cmp.in_set(bk, x, [1, 2, 3, 4]))},
        {"operator": "aggregation", "formula": "log(n)/p  (rotations only)",
         "predicted": 0,
         "measured": _measure(lambda bk, x, y: bk.sum_slots(x))},
        {"operator": "join (EQ+mask)", "formula": "ceil(log2(p-1)) + 1",
         "predicted": lg + 1,
         "measured": _measure(lambda bk, x, y: bk.mul(cmp.eq_ct(bk, x, y), y))},
        {"operator": "group by (per value)", "formula": "ceil(log2(p-1))",
         "predicted": lg,
         "measured": _measure(lambda bk, x, y: cmp.eq_scalar(bk, x, 3))},
    ]
    for r in rows:
        r["ok"] = r["measured"] <= r["predicted"]
    save_json("table3_depth_model.json", rows)
    return table(rows, "Table 3 — multiplicative depth per operator (t=65537)")


if __name__ == "__main__":
    print(main())
