#!/usr/bin/env python
"""Repo-specific AST lint for the HE engine (CI `static-analysis` job).

Two rules, both born from real bug classes in this codebase:

R001  raw-jnp-mod: modular arithmetic on jax.numpy values (`x % q` with
      a `jnp` reference anywhere in the expression) outside the blessed
      modular layers.  Everything above core/{limbops,ntt,bfv,encoder}
      and kernels/ must go through the limbops dispatch so the Pallas /
      XLA lowering decision stays in one place — a stray `jnp` mod in
      engine code silently bypasses the u32 kernel path.

R002  bare-int64-mul: an integer multiply that names int64 in its
      statement (astype/dtype casts, int64-typed temporaries) without an
      overflow-guard note.  int64 products of 62-bit operands wrap
      silently under JAX; every such site must state its bound (e.g.
      "products < 2^34, exact int64") in a nearby comment or the
      function docstring, or route through kernels/u32.py.

Zero third-party dependencies: stdlib ast only, so the lint runs in any
CI container.  Exit status 1 iff a finding is emitted.

Usage:  python tools/lint_rules.py [paths...]   (default: src/repro)
"""
from __future__ import annotations

import ast
import os
import re
import sys

# Modular layers allowed to use raw jnp modular arithmetic (R001).
MOD_ALLOWLIST = (
    "core/limbops.py",
    "core/ntt.py",
    "core/bfv.py",
    "core/encoder.py",
    "kernels/",
)

# A multiply counts as overflow-guarded if one of these appears in its
# statement's trailing comments, the line above, or the enclosing
# function's docstring.
GUARD_RE = re.compile(
    r"overflow|exact int64|exact in int64|< *2[\^*][\^*]?\d+"
    r"|2[\^*][\^*]?\d+ *[-—] *exact|< *\w+[\^*][\^*]?2\b|fits int64",
    re.IGNORECASE)

INT64_RE = re.compile(r"\bu?int64\b")


def _contains_jnp(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id == "jnp"
               for n in ast.walk(node))


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, src: str):
        self.path = path
        self.lines = src.splitlines()
        self.findings: list[tuple[str, int, str]] = []
        self.doc_stack: list[str] = []
        self.rel = path.replace(os.sep, "/")

    # -- helpers ---------------------------------------------------------
    def _line(self, i: int) -> str:
        return self.lines[i - 1] if 1 <= i <= len(self.lines) else ""

    def _guarded(self, node: ast.BinOp) -> bool:
        ctx = [self._line(node.lineno), self._line(node.lineno - 1),
               self._line(getattr(node, "end_lineno", node.lineno))]
        if any(GUARD_RE.search(t) for t in ctx):
            return True
        return any(GUARD_RE.search(doc) for doc in self.doc_stack if doc)

    def _statement_text(self, node: ast.AST) -> str:
        lo = node.lineno
        hi = getattr(node, "end_lineno", lo)
        return "\n".join(self._line(i) for i in range(lo, hi + 1))

    # -- scope tracking for docstring guards -----------------------------
    def _visit_scope(self, node):
        self.doc_stack.append(ast.get_docstring(node) or "")
        self.generic_visit(node)
        self.doc_stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = visit_ClassDef = _visit_scope

    # -- the rules -------------------------------------------------------
    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, ast.Mod):
            if (not any(self.rel.endswith(p) or ("/" + p) in self.rel
                        for p in MOD_ALLOWLIST if p.endswith(".py"))
                    and not any(("/" + p) in self.rel for p in MOD_ALLOWLIST
                                if p.endswith("/"))
                    and _contains_jnp(node)):
                self.findings.append((
                    "R001", node.lineno,
                    "raw jax.numpy modular arithmetic outside the "
                    "limbops/ntt/bfv dispatch layers — route through "
                    "core.limbops so the kernel lowering stays unified"))
        elif isinstance(node.op, ast.Mult):
            text = self._statement_text(node)
            if INT64_RE.search(text) and not self._guarded(node):
                self.findings.append((
                    "R002", node.lineno,
                    "int64 multiply without an overflow-guard note — "
                    "state the product bound (e.g. '< 2^34, exact "
                    "int64') in a comment/docstring or use kernels.u32"))
        self.generic_visit(node)


def lint_file(path: str) -> list[tuple[str, str, int, str]]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:  # pragma: no cover - repo code always parses
        return [("R000", path, e.lineno or 0, f"syntax error: {e.msg}")]
    v = _Visitor(path, src)
    v.doc_stack.append(ast.get_docstring(tree) or "")
    v.visit(tree)
    return [(code, path, line, msg) for code, line, msg in v.findings]


def lint_paths(paths: list[str]) -> list[tuple[str, str, int, str]]:
    findings = []
    for root in paths:
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, _dirs, files in os.walk(root):
            for fn in sorted(files):
                if fn.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fn)))
    return findings


def main(argv: list[str] | None = None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or ["src/repro"]
    findings = lint_paths(args)
    for code, path, line, msg in findings:
        print(f"{path}:{line}: {code} {msg}")
    print(f"lint_rules: {len(findings)} finding(s) over {args}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
